//! Batch-adaptive speculation control — the β-aware batching policy.
//!
//! The speculative-decoding survey (Xia et al., 2024) observes that
//! batching interacts with acceptance-rate dynamics: verifying a B-sequence
//! batch multiplies the tree-verification FLOPs by B, so the tree width
//! that maximizes throughput *shrinks* as the decode batch grows, while a
//! lonely interactive sequence should spend the idle verify capacity on a
//! wider/deeper tree. The `BetaController` implements that trade: per round
//! it derives a `DraftPlan` (beam width, candidate depth, tree-node budget)
//! from the current decode batch size and an EWMA of per-sequence
//! acceptance, and the engine threads the plan through the drafter and the
//! token-tree builder.
//!
//! Everything here is pure integer/f64 arithmetic on observed counts —
//! no clocks, no RNG — so scheduler replays with `--beta-policy adaptive`
//! stay byte-for-byte deterministic (the chosen plan is additionally
//! recorded in the scheduler event log whenever it changes).
//!
//! `SpecPolicy` (PR 10) extends the controller into a per-slot drafter
//! portfolio policy: each sequence carries a `SpecState` with per-drafter
//! acceptance EWMAs, and under `--spec-policy auto` the policy re-selects
//! the slot's drafter online (score = acceptance EWMA − draft cost, with
//! dwell + hysteresis so one noisy round cannot thrash the choice). Like
//! the β controller it is pure arithmetic on observed counts, so drafter
//! switches replay byte-for-byte and are logged as `DrafterSwitch` sched
//! events.

use anyhow::{bail, Result};

use crate::drafters::DrafterKind;

/// Which β policy the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BetaPolicy {
    /// Paper-default static budget: `max_paths` beams, `tree_n` nodes,
    /// `ctc_target_u` depth, regardless of batch size.
    Fixed,
    /// Batch- and acceptance-adaptive budget (see `BetaController::plan`).
    Adaptive,
}

impl BetaPolicy {
    pub fn parse(s: &str) -> Result<BetaPolicy> {
        Ok(match s {
            "fixed" => BetaPolicy::Fixed,
            "adaptive" => BetaPolicy::Adaptive,
            other => bail!("unknown beta policy '{other}' (fixed|adaptive)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BetaPolicy::Fixed => "fixed",
            BetaPolicy::Adaptive => "adaptive",
        }
    }
}

/// Per-round draft budget handed to the drafter and the tree builder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DraftPlan {
    /// beam width — max candidate paths drafted per sequence
    pub max_paths: usize,
    /// max candidate continuation length (tree depth)
    pub max_len: usize,
    /// max token-tree nodes per sequence (including the root)
    pub tree_nodes: usize,
}

/// EWMA smoothing factor for the acceptance signal. Small enough that one
/// lucky round does not whipsaw the tree shape, large enough to adapt
/// within a few tens of rounds.
const EWMA_ALPHA: f64 = 0.1;

/// Smallest adaptive tree-node budget (root + a couple of branches) — below
/// this the draft overhead is not worth a verify pass at all.
const MIN_NODES: usize = 4;

/// Derives the per-round `DraftPlan` from decode batch size and an EWMA of
/// per-sequence accepted tokens per round. Deterministic in its inputs.
#[derive(Debug, Clone)]
pub struct BetaController {
    policy: BetaPolicy,
    /// fixed-policy budget (engine config / manifest constants)
    base_paths: usize,
    base_nodes: usize,
    base_len: usize,
    /// EWMA of accepted tokens per sequence per decode round
    ewma: f64,
    /// degradation-ladder override: speculation forced off (every plan is
    /// the single-node plain-decode plan) regardless of policy
    forced_plain: bool,
}

impl BetaController {
    /// `base_paths`/`base_nodes`/`base_len` are the static budgets the
    /// `Fixed` policy always returns (engine: `max_paths`, `tree_n`,
    /// `ctc_target_u`).
    pub fn new(policy: BetaPolicy, base_paths: usize, base_nodes: usize,
               base_len: usize) -> BetaController {
        BetaController {
            policy,
            base_paths: base_paths.max(1),
            // never inflated past the caller's budget: the engine verifies
            // at most `tree_n` nodes, so a plan must never exceed it
            base_nodes: base_nodes.max(1),
            base_len: base_len.max(1),
            // optimistic start: behave like Fixed until evidence arrives
            ewma: base_len.max(1) as f64,
            forced_plain: false,
        }
    }

    pub fn policy(&self) -> BetaPolicy {
        self.policy
    }

    /// Degradation-ladder hook (`supervisor::Rung::NoSpec` and above):
    /// while set, `plan` returns the single-node plain-decode plan — a
    /// lossless fallback that sheds all draft/verify overhead under
    /// pressure. The acceptance EWMA keeps updating so re-enabling
    /// speculation resumes from current evidence.
    pub fn force_plain(&mut self, on: bool) {
        self.forced_plain = on;
    }

    pub fn is_forced_plain(&self) -> bool {
        self.forced_plain
    }

    /// Current acceptance EWMA (tokens per sequence per round).
    pub fn ewma_accept(&self) -> f64 {
        self.ewma
    }

    /// Record one sequence's accepted-token count for a decode round.
    pub fn observe(&mut self, accepted: usize) {
        self.ewma = (1.0 - EWMA_ALPHA) * self.ewma
            + EWMA_ALPHA * accepted as f64;
    }

    /// The draft budget for a decode round over `batch` sequences.
    ///
    /// Adaptive shape:
    /// * node budget divides the fixed budget by the batch size (verify
    ///   FLOPs are `batch × nodes`), floored at `MIN_NODES` — so a full
    ///   batch runs narrow trees and a lonely sequence gets the whole
    ///   budget;
    /// * depth tracks acceptance: draft one level past what is currently
    ///   being accepted (EWMA), clamped to the trained target length;
    /// * beam width never exceeds what the node budget can hold.
    pub fn plan(&self, batch: usize) -> DraftPlan {
        if self.forced_plain {
            // one path, one level, root-only tree: pure autoregressive
            // decode — the engine's tree builder degenerates to a single
            // next-token verify, so correctness is unchanged
            return DraftPlan { max_paths: 1, max_len: 1, tree_nodes: 1 };
        }
        match self.policy {
            BetaPolicy::Fixed => DraftPlan {
                max_paths: self.base_paths,
                max_len: self.base_len,
                tree_nodes: self.base_nodes,
            },
            BetaPolicy::Adaptive => {
                let batch = batch.max(1);
                let nodes = (self.base_nodes / batch)
                    .clamp(MIN_NODES.min(self.base_nodes), self.base_nodes);
                let depth = (self.ewma.ceil() as usize + 1)
                    .clamp(2.min(self.base_len), self.base_len);
                let paths = self
                    .base_paths
                    .min(nodes.saturating_sub(1))
                    .max(1);
                DraftPlan { max_paths: paths, max_len: depth, tree_nodes: nodes }
            }
        }
    }
}

// ================================================================ SpecPolicy
/// How the per-slot drafter choice is made.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecMode {
    /// Every slot runs the portfolio's primary drafter (the engine-config
    /// method) — byte-for-byte today's behavior.
    Fixed,
    /// Per-slot online selection from the acceptance EWMAs (see
    /// `SpecPolicy::observe`).
    Auto,
    /// Speculation off: every slot plain-decodes (`DrafterKind::None`).
    Off,
}

impl SpecMode {
    pub fn parse(s: &str) -> Result<SpecMode> {
        Ok(match s {
            "fixed" => SpecMode::Fixed,
            "auto" => SpecMode::Auto,
            "off" => SpecMode::Off,
            other => bail!("unknown spec policy '{other}' (fixed|auto|off)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpecMode::Fixed => "fixed",
            SpecMode::Auto => "auto",
            SpecMode::Off => "off",
        }
    }
}

/// Rounds a slot must dwell on its current drafter before the policy may
/// switch it again — one switch per dwell window bounds thrash.
pub const SPEC_MIN_DWELL: u32 = 6;

/// A challenger must beat the incumbent's score by this margin (accepted
/// tokens/round) to take the slot — hysteresis against EWMA noise.
pub const SPEC_HYST: f64 = 0.1;

/// Per-slot EWMA smoothing — faster than the global `EWMA_ALPHA` so the
/// choice adapts within one sequence's lifetime.
const SLOT_ALPHA: f64 = 0.2;

/// Per-sequence speculation state: the slot's current drafter, per-drafter
/// acceptance evidence, and the dwell counter. Fixed-size (indexed by
/// `DrafterKind`) so it lives inline in the slot with zero allocation.
#[derive(Debug, Clone, PartialEq)]
pub struct SpecState {
    cur: DrafterKind,
    /// per-kind EWMA of accepted tokens per round; untried speculative
    /// kinds start optimistic (≈ base_len + 1) so each gets explored
    ewma: [f64; DrafterKind::COUNT],
    dwell: u32,
    /// per-request drafter pin (wire `drafter` field)
    pinned: Option<DrafterKind>,
    /// per-request mode override (wire `spec` field: auto | off)
    mode: Option<SpecMode>,
}

impl SpecState {
    /// The kind the online selector currently favors (pre pin/off/force
    /// overrides — see `SpecPolicy::resolve`).
    pub fn current(&self) -> DrafterKind {
        self.cur
    }

    pub fn pinned(&self) -> Option<DrafterKind> {
        self.pinned
    }

    pub fn mode_override(&self) -> Option<SpecMode> {
        self.mode
    }

    /// Acceptance EWMA for one kind (tests / gauges).
    pub fn kind_ewma(&self, k: DrafterKind) -> f64 {
        self.ewma[k.idx()]
    }
}

/// The drafter-portfolio policy: owns the β controller plus the portfolio
/// composition, per-kind global acceptance telemetry, and the per-slot
/// selection rule. Pure arithmetic on observed counts — no clocks, no RNG
/// — so `MockSched`/`MockCluster` run the identical object and sim replays
/// stay byte-stable.
#[derive(Debug, Clone)]
pub struct SpecPolicy {
    beta: BetaController,
    mode: SpecMode,
    /// portfolio composition; `kinds[0]` is the primary (engine-config
    /// method) and the Fixed-mode choice
    kinds: Vec<DrafterKind>,
    primary: DrafterKind,
    /// optimistic EWMA start for untried speculative kinds
    optimistic: f64,
    /// global per-kind telemetry (sched.spec.* gauges)
    kind_rounds: [u64; DrafterKind::COUNT],
    kind_accepted: [u64; DrafterKind::COUNT],
    kind_ewma: [f64; DrafterKind::COUNT],
    switches: u64,
}

impl SpecPolicy {
    /// `kinds[0]` must be the primary drafter (the engine-config method);
    /// an empty portfolio degenerates to plain decode.
    pub fn new(beta: BetaController, mode: SpecMode,
               kinds: Vec<DrafterKind>) -> SpecPolicy {
        let primary = kinds.first().copied().unwrap_or(DrafterKind::None);
        let optimistic = (beta.base_len + 1) as f64;
        let mut kind_ewma = [1.0; DrafterKind::COUNT];
        for &k in &kinds {
            if k.is_speculative() {
                kind_ewma[k.idx()] = optimistic;
            }
        }
        SpecPolicy {
            beta,
            mode,
            kinds,
            primary,
            optimistic,
            kind_rounds: [0; DrafterKind::COUNT],
            kind_accepted: [0; DrafterKind::COUNT],
            kind_ewma,
            switches: 0,
        }
    }

    pub fn mode(&self) -> SpecMode {
        self.mode
    }

    pub fn kinds(&self) -> &[DrafterKind] {
        &self.kinds
    }

    pub fn primary(&self) -> DrafterKind {
        self.primary
    }

    pub fn contains(&self, k: DrafterKind) -> bool {
        k == DrafterKind::None || self.kinds.contains(&k)
    }

    /// Re-point the selection domain at a new portfolio composition
    /// (engine `set_method`): primary and kinds change, β evidence and
    /// per-kind telemetry are kept — matching the old behavior where a
    /// method swap rebuilt the drafter but not the controller.
    pub fn set_portfolio(&mut self, kinds: Vec<DrafterKind>) {
        self.primary = kinds.first().copied().unwrap_or(DrafterKind::None);
        self.kinds = kinds;
    }

    // β-controller delegation — existing call sites keep working.
    pub fn policy(&self) -> BetaPolicy {
        self.beta.policy()
    }

    pub fn plan(&self, batch: usize) -> DraftPlan {
        self.beta.plan(batch)
    }

    pub fn force_plain(&mut self, on: bool) {
        self.beta.force_plain(on);
    }

    pub fn is_forced_plain(&self) -> bool {
        self.beta.is_forced_plain()
    }

    pub fn ewma_accept(&self) -> f64 {
        self.beta.ewma_accept()
    }

    /// Fresh per-slot state for an admitted sequence. `pinned`/`mode` are
    /// the request's wire overrides (None = engine defaults).
    pub fn new_state(&self, pinned: Option<DrafterKind>,
                     mode: Option<SpecMode>) -> SpecState {
        let mut ewma = [1.0; DrafterKind::COUNT];
        for &k in &self.kinds {
            if k.is_speculative() {
                ewma[k.idx()] = self.optimistic;
            }
        }
        SpecState {
            cur: pinned.unwrap_or(self.primary),
            ewma,
            dwell: 0,
            pinned,
            mode,
        }
    }

    fn effective_mode(&self, state: &SpecState) -> SpecMode {
        state.mode.unwrap_or(self.mode)
    }

    /// score = how many tokens/round the kind is worth net of its draft
    /// cost; higher wins the slot
    fn score(&self, state: &SpecState, k: DrafterKind) -> f64 {
        state.ewma[k.idx()] - k.draft_cost()
    }

    /// The drafter this slot runs THIS round, after every override:
    /// degradation-ladder force-plain and mode `off` shed all speculation,
    /// a wire pin wins over learning, `fixed` always runs the primary.
    pub fn resolve(&self, state: &SpecState) -> DrafterKind {
        if self.beta.is_forced_plain() {
            return DrafterKind::None;
        }
        match self.effective_mode(state) {
            SpecMode::Off => DrafterKind::None,
            SpecMode::Fixed => state.pinned.unwrap_or(self.primary),
            SpecMode::Auto => state.pinned.unwrap_or(state.cur),
        }
    }

    /// Record one sequence's accepted-token count for a decode round
    /// (feeds the global β EWMA too) and, under `auto`, re-select the
    /// slot's drafter. Returns `Some((from, to))` when the slot switched —
    /// the caller logs it as a `DrafterSwitch` sched event.
    pub fn observe(&mut self, state: &mut SpecState,
                   accepted: usize) -> Option<(DrafterKind, DrafterKind)> {
        self.beta.observe(accepted);
        let ran = self.resolve(state);
        let i = ran.idx();
        state.ewma[i] =
            (1.0 - SLOT_ALPHA) * state.ewma[i] + SLOT_ALPHA * accepted as f64;
        self.kind_rounds[i] += 1;
        self.kind_accepted[i] += accepted as u64;
        self.kind_ewma[i] = (1.0 - EWMA_ALPHA) * self.kind_ewma[i]
            + EWMA_ALPHA * accepted as f64;
        state.dwell = state.dwell.saturating_add(1);
        if self.effective_mode(state) != SpecMode::Auto
            || state.pinned.is_some()
            || self.beta.is_forced_plain()
            || state.dwell < SPEC_MIN_DWELL
        {
            return None;
        }
        let cur_score = self.score(state, state.cur);
        let mut best = state.cur;
        let mut best_score = cur_score;
        for &k in &self.kinds {
            if k == state.cur {
                continue;
            }
            let s = self.score(state, k);
            // strict > keeps ties on the earlier (portfolio-order) kind —
            // total and deterministic
            if s > best_score {
                best = k;
                best_score = s;
            }
        }
        if best != state.cur && best_score > cur_score + SPEC_HYST {
            let from = state.cur;
            state.cur = best;
            state.dwell = 0;
            self.switches += 1;
            return Some((from, best));
        }
        None
    }

    // Telemetry for the sched.spec.* gauges.
    pub fn switches(&self) -> u64 {
        self.switches
    }

    pub fn kind_rounds(&self, k: DrafterKind) -> u64 {
        self.kind_rounds[k.idx()]
    }

    pub fn kind_accepted(&self, k: DrafterKind) -> u64 {
        self.kind_accepted[k.idx()]
    }

    pub fn kind_ewma(&self, k: DrafterKind) -> f64 {
        self.kind_ewma[k.idx()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in [BetaPolicy::Fixed, BetaPolicy::Adaptive] {
            assert_eq!(BetaPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(BetaPolicy::parse("auto").is_err());
    }

    #[test]
    fn fixed_policy_ignores_batch_and_acceptance() {
        let mut c = BetaController::new(BetaPolicy::Fixed, 16, 32, 6);
        let base = c.plan(1);
        assert_eq!(base,
                   DraftPlan { max_paths: 16, max_len: 6, tree_nodes: 32 });
        for _ in 0..50 {
            c.observe(0);
        }
        assert_eq!(c.plan(8), base);
    }

    #[test]
    fn adaptive_shrinks_trees_as_batch_grows() {
        let c = BetaController::new(BetaPolicy::Adaptive, 16, 32, 6);
        let widths: Vec<usize> =
            (1..=8).map(|b| c.plan(b).tree_nodes).collect();
        assert_eq!(widths[0], 32, "lonely sequence gets the full budget");
        for w in widths.windows(2) {
            assert!(w[0] >= w[1], "node budget must shrink with batch");
        }
        assert!(*widths.last().unwrap() >= 4, "floor respected");
        // beam width always fits the node budget
        for b in 1..=8 {
            let p = c.plan(b);
            assert!(p.max_paths <= p.tree_nodes.saturating_sub(1).max(1));
            assert!(p.max_paths >= 1 && p.max_len >= 1);
        }
    }

    #[test]
    fn adaptive_depth_tracks_acceptance_ewma() {
        let mut c = BetaController::new(BetaPolicy::Adaptive, 16, 32, 6);
        assert_eq!(c.plan(1).max_len, 6, "optimistic before evidence");
        for _ in 0..200 {
            c.observe(0); // nothing accepted: draft shallow
        }
        assert_eq!(c.plan(1).max_len, 2);
        for _ in 0..200 {
            c.observe(6); // deep acceptance: draft back to the cap
        }
        assert_eq!(c.plan(1).max_len, 6);
        assert!(c.ewma_accept() > 5.0);
    }

    #[test]
    fn degenerate_budgets_are_never_inflated() {
        // a manifest with tree_n == 1 must yield single-node plans — the
        // engine verifies at most tree_n nodes per sequence
        for policy in [BetaPolicy::Fixed, BetaPolicy::Adaptive] {
            let mut c = BetaController::new(policy, 1, 1, 1);
            for batch in [1usize, 2, 8] {
                let p = c.plan(batch);
                assert!(p.tree_nodes <= 1, "{policy:?}: {p:?}");
                assert!(p.max_paths >= 1 && p.max_len >= 1);
            }
            c.observe(5);
            assert!(c.plan(1).tree_nodes <= 1);
        }
    }

    #[test]
    fn force_plain_overrides_any_policy_and_is_reversible() {
        for policy in [BetaPolicy::Fixed, BetaPolicy::Adaptive] {
            let mut c = BetaController::new(policy, 16, 32, 6);
            let before = c.plan(2);
            c.force_plain(true);
            assert!(c.is_forced_plain());
            assert_eq!(c.plan(2),
                       DraftPlan { max_paths: 1, max_len: 1, tree_nodes: 1 });
            // evidence keeps flowing while degraded
            c.observe(1);
            c.force_plain(false);
            assert_eq!(c.plan(2).tree_nodes, before.tree_nodes,
                       "{policy:?}: leaving no-spec restores the budget");
        }
    }

    #[test]
    fn plans_are_deterministic_in_observation_history() {
        let run = || {
            let mut c = BetaController::new(BetaPolicy::Adaptive, 16, 32, 6);
            let mut plans = Vec::new();
            for i in 0..100usize {
                c.observe(i % 5);
                plans.push(c.plan(1 + i % 4));
            }
            plans
        };
        assert_eq!(run(), run());
    }

    // ------------------------------------------------------- SpecPolicy
    fn auto_policy() -> SpecPolicy {
        SpecPolicy::new(
            BetaController::new(BetaPolicy::Fixed, 16, 32, 6),
            SpecMode::Auto,
            vec![DrafterKind::Ctc, DrafterKind::Lookup, DrafterKind::None],
        )
    }

    #[test]
    fn spec_mode_parse_roundtrip() {
        for m in [SpecMode::Fixed, SpecMode::Auto, SpecMode::Off] {
            assert_eq!(SpecMode::parse(m.name()).unwrap(), m);
        }
        assert!(SpecMode::parse("adaptive").is_err());
    }

    #[test]
    fn fixed_mode_never_switches_and_resolves_primary() {
        let mut p = SpecPolicy::new(
            BetaController::new(BetaPolicy::Fixed, 16, 32, 6),
            SpecMode::Fixed,
            vec![DrafterKind::Ctc, DrafterKind::Lookup, DrafterKind::None],
        );
        let mut s = p.new_state(None, None);
        for _ in 0..200 {
            assert_eq!(p.resolve(&s), DrafterKind::Ctc);
            assert!(p.observe(&mut s, 0).is_none());
        }
        assert_eq!(p.switches(), 0);
        assert_eq!(p.resolve(&s), DrafterKind::Ctc);
    }

    #[test]
    fn rejection_heavy_auto_demotes_to_none() {
        let mut p = auto_policy();
        let mut s = p.new_state(None, None);
        let mut trail = Vec::new();
        // every drafter only ever yields the mandatory 1 token/round: the
        // slot must explore, give up on speculation, and settle on None
        for _ in 0..200 {
            if let Some(sw) = p.observe(&mut s, 1) {
                trail.push(sw);
            }
        }
        assert_eq!(p.resolve(&s), DrafterKind::None, "trail: {trail:?}");
        assert!(trail.last().unwrap().1 == DrafterKind::None);
        // settled: no switch in the tail of the run
        let mut tail = 0;
        for _ in 0..100 {
            if p.observe(&mut s, 1).is_some() {
                tail += 1;
            }
        }
        assert_eq!(tail, 0, "None must be terminal under flat rejection");
    }

    #[test]
    fn copy_heavy_auto_migrates_to_lookup_and_chat_keeps_ctc() {
        // copy-heavy: lookup is worth ~4.5 tokens/round, ctc ~2.5
        let mut p = auto_policy();
        let mut s = p.new_state(None, None);
        for _ in 0..200 {
            let accepted =
                if p.resolve(&s) == DrafterKind::Lookup { 4 } else { 2 };
            p.observe(&mut s, accepted);
        }
        assert_eq!(p.resolve(&s), DrafterKind::Lookup);

        // chat: ctc is worth ~2.5, lookup ~1 — the slot must come home
        let mut p = auto_policy();
        let mut s = p.new_state(None, None);
        for _ in 0..200 {
            let accepted =
                if p.resolve(&s) == DrafterKind::Ctc { 3 } else { 1 };
            p.observe(&mut s, accepted);
        }
        assert_eq!(p.resolve(&s), DrafterKind::Ctc);
    }

    #[test]
    fn dwell_bounds_switch_rate() {
        let mut p = auto_policy();
        let mut s = p.new_state(None, None);
        let rounds = 300u32;
        for i in 0..rounds {
            // adversarial alternating evidence tries to thrash the choice
            p.observe(&mut s, if i % 2 == 0 { 6 } else { 0 });
        }
        assert!(p.switches() <= (rounds / SPEC_MIN_DWELL) as u64,
                "switches {} exceed one per dwell window", p.switches());
    }

    #[test]
    fn pin_and_off_overrides_win() {
        let mut p = auto_policy();
        let mut pinned = p.new_state(Some(DrafterKind::Lookup), None);
        for _ in 0..100 {
            assert_eq!(p.resolve(&pinned), DrafterKind::Lookup);
            assert!(p.observe(&mut pinned, 0).is_none(),
                    "a pinned slot never switches");
        }
        let mut off = p.new_state(None, Some(SpecMode::Off));
        assert_eq!(p.resolve(&off), DrafterKind::None);
        assert!(p.observe(&mut off, 5).is_none());
        // ladder force-plain sheds speculation for every slot
        let auto = p.new_state(None, None);
        p.force_plain(true);
        assert_eq!(p.resolve(&auto), DrafterKind::None);
        p.force_plain(false);
        assert_eq!(p.resolve(&auto), DrafterKind::Ctc);
    }

    #[test]
    fn switch_sequences_are_deterministic() {
        let run = || {
            let mut p = auto_policy();
            let mut s = p.new_state(None, None);
            let mut switches = Vec::new();
            for i in 0..400usize {
                let accepted = match p.resolve(&s) {
                    DrafterKind::Lookup => (i / 60) % 5,
                    DrafterKind::Ctc => 2,
                    _ => 1,
                };
                if let Some(sw) = p.observe(&mut s, accepted) {
                    switches.push((i, sw));
                }
            }
            switches
        };
        let a = run();
        assert_eq!(a, run());
        assert!(!a.is_empty(), "the drive pattern must actually switch");
    }
}
