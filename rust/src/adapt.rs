//! Batch-adaptive speculation control — the β-aware batching policy.
//!
//! The speculative-decoding survey (Xia et al., 2024) observes that
//! batching interacts with acceptance-rate dynamics: verifying a B-sequence
//! batch multiplies the tree-verification FLOPs by B, so the tree width
//! that maximizes throughput *shrinks* as the decode batch grows, while a
//! lonely interactive sequence should spend the idle verify capacity on a
//! wider/deeper tree. The `BetaController` implements that trade: per round
//! it derives a `DraftPlan` (beam width, candidate depth, tree-node budget)
//! from the current decode batch size and an EWMA of per-sequence
//! acceptance, and the engine threads the plan through the drafter and the
//! token-tree builder.
//!
//! Everything here is pure integer/f64 arithmetic on observed counts —
//! no clocks, no RNG — so scheduler replays with `--beta-policy adaptive`
//! stay byte-for-byte deterministic (the chosen plan is additionally
//! recorded in the scheduler event log whenever it changes).

use anyhow::{bail, Result};

/// Which β policy the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BetaPolicy {
    /// Paper-default static budget: `max_paths` beams, `tree_n` nodes,
    /// `ctc_target_u` depth, regardless of batch size.
    Fixed,
    /// Batch- and acceptance-adaptive budget (see `BetaController::plan`).
    Adaptive,
}

impl BetaPolicy {
    pub fn parse(s: &str) -> Result<BetaPolicy> {
        Ok(match s {
            "fixed" => BetaPolicy::Fixed,
            "adaptive" => BetaPolicy::Adaptive,
            other => bail!("unknown beta policy '{other}' (fixed|adaptive)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BetaPolicy::Fixed => "fixed",
            BetaPolicy::Adaptive => "adaptive",
        }
    }
}

/// Per-round draft budget handed to the drafter and the tree builder.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DraftPlan {
    /// beam width — max candidate paths drafted per sequence
    pub max_paths: usize,
    /// max candidate continuation length (tree depth)
    pub max_len: usize,
    /// max token-tree nodes per sequence (including the root)
    pub tree_nodes: usize,
}

/// EWMA smoothing factor for the acceptance signal. Small enough that one
/// lucky round does not whipsaw the tree shape, large enough to adapt
/// within a few tens of rounds.
const EWMA_ALPHA: f64 = 0.1;

/// Smallest adaptive tree-node budget (root + a couple of branches) — below
/// this the draft overhead is not worth a verify pass at all.
const MIN_NODES: usize = 4;

/// Derives the per-round `DraftPlan` from decode batch size and an EWMA of
/// per-sequence accepted tokens per round. Deterministic in its inputs.
#[derive(Debug, Clone)]
pub struct BetaController {
    policy: BetaPolicy,
    /// fixed-policy budget (engine config / manifest constants)
    base_paths: usize,
    base_nodes: usize,
    base_len: usize,
    /// EWMA of accepted tokens per sequence per decode round
    ewma: f64,
    /// degradation-ladder override: speculation forced off (every plan is
    /// the single-node plain-decode plan) regardless of policy
    forced_plain: bool,
}

impl BetaController {
    /// `base_paths`/`base_nodes`/`base_len` are the static budgets the
    /// `Fixed` policy always returns (engine: `max_paths`, `tree_n`,
    /// `ctc_target_u`).
    pub fn new(policy: BetaPolicy, base_paths: usize, base_nodes: usize,
               base_len: usize) -> BetaController {
        BetaController {
            policy,
            base_paths: base_paths.max(1),
            // never inflated past the caller's budget: the engine verifies
            // at most `tree_n` nodes, so a plan must never exceed it
            base_nodes: base_nodes.max(1),
            base_len: base_len.max(1),
            // optimistic start: behave like Fixed until evidence arrives
            ewma: base_len.max(1) as f64,
            forced_plain: false,
        }
    }

    pub fn policy(&self) -> BetaPolicy {
        self.policy
    }

    /// Degradation-ladder hook (`supervisor::Rung::NoSpec` and above):
    /// while set, `plan` returns the single-node plain-decode plan — a
    /// lossless fallback that sheds all draft/verify overhead under
    /// pressure. The acceptance EWMA keeps updating so re-enabling
    /// speculation resumes from current evidence.
    pub fn force_plain(&mut self, on: bool) {
        self.forced_plain = on;
    }

    pub fn is_forced_plain(&self) -> bool {
        self.forced_plain
    }

    /// Current acceptance EWMA (tokens per sequence per round).
    pub fn ewma_accept(&self) -> f64 {
        self.ewma
    }

    /// Record one sequence's accepted-token count for a decode round.
    pub fn observe(&mut self, accepted: usize) {
        self.ewma = (1.0 - EWMA_ALPHA) * self.ewma
            + EWMA_ALPHA * accepted as f64;
    }

    /// The draft budget for a decode round over `batch` sequences.
    ///
    /// Adaptive shape:
    /// * node budget divides the fixed budget by the batch size (verify
    ///   FLOPs are `batch × nodes`), floored at `MIN_NODES` — so a full
    ///   batch runs narrow trees and a lonely sequence gets the whole
    ///   budget;
    /// * depth tracks acceptance: draft one level past what is currently
    ///   being accepted (EWMA), clamped to the trained target length;
    /// * beam width never exceeds what the node budget can hold.
    pub fn plan(&self, batch: usize) -> DraftPlan {
        if self.forced_plain {
            // one path, one level, root-only tree: pure autoregressive
            // decode — the engine's tree builder degenerates to a single
            // next-token verify, so correctness is unchanged
            return DraftPlan { max_paths: 1, max_len: 1, tree_nodes: 1 };
        }
        match self.policy {
            BetaPolicy::Fixed => DraftPlan {
                max_paths: self.base_paths,
                max_len: self.base_len,
                tree_nodes: self.base_nodes,
            },
            BetaPolicy::Adaptive => {
                let batch = batch.max(1);
                let nodes = (self.base_nodes / batch)
                    .clamp(MIN_NODES.min(self.base_nodes), self.base_nodes);
                let depth = (self.ewma.ceil() as usize + 1)
                    .clamp(2.min(self.base_len), self.base_len);
                let paths = self
                    .base_paths
                    .min(nodes.saturating_sub(1))
                    .max(1);
                DraftPlan { max_paths: paths, max_len: depth, tree_nodes: nodes }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_roundtrip() {
        for p in [BetaPolicy::Fixed, BetaPolicy::Adaptive] {
            assert_eq!(BetaPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(BetaPolicy::parse("auto").is_err());
    }

    #[test]
    fn fixed_policy_ignores_batch_and_acceptance() {
        let mut c = BetaController::new(BetaPolicy::Fixed, 16, 32, 6);
        let base = c.plan(1);
        assert_eq!(base,
                   DraftPlan { max_paths: 16, max_len: 6, tree_nodes: 32 });
        for _ in 0..50 {
            c.observe(0);
        }
        assert_eq!(c.plan(8), base);
    }

    #[test]
    fn adaptive_shrinks_trees_as_batch_grows() {
        let c = BetaController::new(BetaPolicy::Adaptive, 16, 32, 6);
        let widths: Vec<usize> =
            (1..=8).map(|b| c.plan(b).tree_nodes).collect();
        assert_eq!(widths[0], 32, "lonely sequence gets the full budget");
        for w in widths.windows(2) {
            assert!(w[0] >= w[1], "node budget must shrink with batch");
        }
        assert!(*widths.last().unwrap() >= 4, "floor respected");
        // beam width always fits the node budget
        for b in 1..=8 {
            let p = c.plan(b);
            assert!(p.max_paths <= p.tree_nodes.saturating_sub(1).max(1));
            assert!(p.max_paths >= 1 && p.max_len >= 1);
        }
    }

    #[test]
    fn adaptive_depth_tracks_acceptance_ewma() {
        let mut c = BetaController::new(BetaPolicy::Adaptive, 16, 32, 6);
        assert_eq!(c.plan(1).max_len, 6, "optimistic before evidence");
        for _ in 0..200 {
            c.observe(0); // nothing accepted: draft shallow
        }
        assert_eq!(c.plan(1).max_len, 2);
        for _ in 0..200 {
            c.observe(6); // deep acceptance: draft back to the cap
        }
        assert_eq!(c.plan(1).max_len, 6);
        assert!(c.ewma_accept() > 5.0);
    }

    #[test]
    fn degenerate_budgets_are_never_inflated() {
        // a manifest with tree_n == 1 must yield single-node plans — the
        // engine verifies at most tree_n nodes per sequence
        for policy in [BetaPolicy::Fixed, BetaPolicy::Adaptive] {
            let mut c = BetaController::new(policy, 1, 1, 1);
            for batch in [1usize, 2, 8] {
                let p = c.plan(batch);
                assert!(p.tree_nodes <= 1, "{policy:?}: {p:?}");
                assert!(p.max_paths >= 1 && p.max_len >= 1);
            }
            c.observe(5);
            assert!(c.plan(1).tree_nodes <= 1);
        }
    }

    #[test]
    fn force_plain_overrides_any_policy_and_is_reversible() {
        for policy in [BetaPolicy::Fixed, BetaPolicy::Adaptive] {
            let mut c = BetaController::new(policy, 16, 32, 6);
            let before = c.plan(2);
            c.force_plain(true);
            assert!(c.is_forced_plain());
            assert_eq!(c.plan(2),
                       DraftPlan { max_paths: 1, max_len: 1, tree_nodes: 1 });
            // evidence keeps flowing while degraded
            c.observe(1);
            c.force_plain(false);
            assert_eq!(c.plan(2).tree_nodes, before.tree_nodes,
                       "{policy:?}: leaving no-spec restores the budget");
        }
    }

    #[test]
    fn plans_are_deterministic_in_observation_history() {
        let run = || {
            let mut c = BetaController::new(BetaPolicy::Adaptive, 16, 32, 6);
            let mut plans = Vec::new();
            for i in 0..100usize {
                c.observe(i % 5);
                plans.push(c.plan(1 + i % 4));
            }
            plans
        };
        assert_eq!(run(), run());
    }
}
