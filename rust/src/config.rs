//! Typed views over `artifacts/manifest.json` plus engine configuration.
//!
//! The manifest is the contract between the python build path and this
//! coordinator: shapes, weight orderings and graph filenames all come from
//! it — nothing shape-like is hard-coded on the rust side.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::adapt::{BetaPolicy, SpecMode};
use crate::drafters::DrafterKind;
use crate::sched::SloPolicy;
use crate::util::json::{parse, Json};

/// Global serving constants exported by the python build.
#[derive(Debug, Clone)]
pub struct Constants {
    pub vocab_size: usize,
    pub blank_id: usize,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub lmax: usize,
    pub tree_n: usize,
    pub prefill_n: usize,
    pub draft_slots: usize,
    pub ctc_target_u: usize,
    pub hidden_win: usize,
    pub medusa_heads: usize,
    pub hydra_steps: usize,
    pub hydra_beams: usize,
    pub head_dim: usize,
    pub batch_sizes: Vec<usize>,
    pub step_ns: Vec<usize>,
    pub ctc_score_batch: usize,
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub family: String,
    pub analog: String,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub act: String,
}

#[derive(Debug, Clone)]
pub struct GraphMeta {
    pub file: String,
    pub batch: usize,
    /// N for step graphs; 0 for draft/kernel graphs.
    pub n: usize,
}

#[derive(Debug, Clone)]
pub struct HeadMeta {
    pub weights_file: String,
    pub weight_order: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub config: ModelConfig,
    pub weights_file: String,
    pub weight_order: Vec<String>,
    pub heads: BTreeMap<String, HeadMeta>,
    pub graphs: BTreeMap<String, GraphMeta>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub constants: Constants,
    pub tokenizer_file: String,
    pub chat_templates: BTreeMap<String, (String, String)>,
    pub models: BTreeMap<String, ModelMeta>,
    pub kernels: BTreeMap<String, GraphMeta>,
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| anyhow!("manifest: missing numeric field '{key}'"))
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)
        .as_str()
        .ok_or_else(|| anyhow!("manifest: missing string field '{key}'"))?
        .to_string())
}

fn str_list(v: &Json) -> Vec<String> {
    v.as_arr()
        .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = parse(&text).map_err(|e| anyhow!("{e}"))?;
        if v.get("version").as_i64() != Some(1) {
            bail!("unsupported manifest version");
        }
        let c = v.get("constants");
        let constants = Constants {
            vocab_size: req_usize(c, "vocab_size")?,
            blank_id: req_usize(c, "blank_id")?,
            pad_id: c.get("pad_id").as_i64().unwrap_or(0) as i32,
            bos_id: c.get("bos_id").as_i64().unwrap_or(1) as i32,
            eos_id: c.get("eos_id").as_i64().unwrap_or(2) as i32,
            lmax: req_usize(c, "lmax")?,
            tree_n: req_usize(c, "tree_n")?,
            prefill_n: req_usize(c, "prefill_n")?,
            draft_slots: req_usize(c, "draft_slots")?,
            ctc_target_u: req_usize(c, "ctc_target_u")?,
            hidden_win: req_usize(c, "hidden_win")?,
            medusa_heads: req_usize(c, "medusa_heads")?,
            hydra_steps: req_usize(c, "hydra_steps")?,
            hydra_beams: req_usize(c, "hydra_beams")?,
            head_dim: req_usize(c, "head_dim")?,
            batch_sizes: c
                .get("batch_sizes")
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_else(|| vec![1, 4]),
            step_ns: c
                .get("step_ns")
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_else(|| vec![1, 32, 64]),
            ctc_score_batch: c.get("ctc_score_batch").as_usize().unwrap_or(16),
        };

        let mut chat_templates = BTreeMap::new();
        if let Some(obj) = v.get("chat_templates").as_obj() {
            for (fam, t) in obj {
                let full = t.idx(0).as_str().unwrap_or("{q} {a}").to_string();
                let prompt = t.idx(1).as_str().unwrap_or("{q}").to_string();
                chat_templates.insert(fam.clone(), (full, prompt));
            }
        }

        let parse_graph = |g: &Json| -> Result<GraphMeta> {
            Ok(GraphMeta {
                file: req_str(g, "file")?,
                batch: g.get("batch").as_usize().unwrap_or(1),
                n: g.get("n").as_usize().unwrap_or(0),
            })
        };

        let mut models = BTreeMap::new();
        if let Some(obj) = v.get("models").as_obj() {
            for (name, m) in obj {
                let cfgv = m.get("config");
                let config = ModelConfig {
                    family: req_str(cfgv, "family")?,
                    analog: req_str(cfgv, "analog")?,
                    layers: req_usize(cfgv, "layers")?,
                    d_model: req_usize(cfgv, "d_model")?,
                    n_heads: req_usize(cfgv, "n_heads")?,
                    d_ff: req_usize(cfgv, "d_ff")?,
                    act: req_str(cfgv, "act")?,
                };
                let mut heads = BTreeMap::new();
                if let Some(hobj) = m.get("heads").as_obj() {
                    for (hname, h) in hobj {
                        heads.insert(
                            hname.clone(),
                            HeadMeta {
                                weights_file: req_str(h, "weights")?,
                                weight_order: str_list(h.get("weight_order")),
                            },
                        );
                    }
                }
                let mut graphs = BTreeMap::new();
                if let Some(gobj) = m.get("graphs").as_obj() {
                    for (gname, g) in gobj {
                        graphs.insert(gname.clone(), parse_graph(g)?);
                    }
                }
                models.insert(
                    name.clone(),
                    ModelMeta {
                        config,
                        weights_file: req_str(m, "weights")?,
                        weight_order: str_list(m.get("weight_order")),
                        heads,
                        graphs,
                    },
                );
            }
        }

        let mut kernels = BTreeMap::new();
        if let Some(kobj) = v.get("kernels").as_obj() {
            for (kname, k) in kobj {
                kernels.insert(kname.clone(), parse_graph(k)?);
            }
        }

        Ok(Manifest {
            dir,
            constants,
            tokenizer_file: req_str(&v, "tokenizer")?,
            chat_templates,
            models,
            kernels,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest (have: {:?})",
                                   self.models.keys().collect::<Vec<_>>()))
    }

    /// Prompt template for a model family ("USER: {q}\nASSISTANT:").
    pub fn prompt_template(&self, family: &str) -> &str {
        self.chat_templates
            .get(family)
            .map(|(_, p)| p.as_str())
            .unwrap_or("{q}")
    }

    /// Pick the smallest exported batch size >= the requested one.
    pub fn pick_batch(&self, want: usize) -> usize {
        let mut sizes = self.constants.batch_sizes.clone();
        sizes.sort_unstable();
        for b in &sizes {
            if *b >= want {
                return *b;
            }
        }
        *sizes.last().unwrap_or(&1)
    }
}

/// Engine-level knobs (speculation method, tree shaping, sampling).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub model: String,
    pub method: Method,
    /// top-k per CTC slot when expanding raw candidates
    pub slot_topk: usize,
    /// number of raw candidate paths kept before CTC transform
    pub max_paths: usize,
    /// disable the CTC transform (Table 2 ablation: "Medusa verify")
    pub ctc_transform: bool,
    pub max_new_tokens: usize,
    /// 0.0 = greedy (paper's setting); >0 enables stochastic acceptance
    pub temperature: f32,
    pub seed: u64,
    /// KV block-pool capacity in positions; 0 = lmax × max slots (never
    /// exhausts). Smaller values turn on real admission pressure: queued
    /// requests wait for pool room and running ones can be preempted.
    /// Under the server this sizes the ONE `SharedBlockPool` every worker
    /// leases from (cluster-wide total; 0 scales the default by the worker
    /// count) — see `Engine::new_leased`.
    pub kv_pool_positions: usize,
    /// Engine-side admit-queue bound; 0 = unbounded. When the queue is at
    /// the cap, `submit` reports `Submission::Busy` (backpressure).
    pub queue_cap: usize,
    /// SLO scheduling policy: priority-class deadlines, batch aging, and
    /// the per-round prefill-chunk budget (see `sched::SloPolicy`).
    pub slo: SloPolicy,
    /// β-aware batching: `fixed` = the paper's static tree budget,
    /// `adaptive` = per-round width/depth from batch size + acceptance
    /// EWMA (see `adapt::BetaController`).
    pub beta_policy: BetaPolicy,
    /// Drafter portfolio available to the speculation policy, in
    /// preference order. Empty = single-drafter portfolio derived from
    /// `method` (today's behavior, byte-for-byte).
    pub drafter_portfolio: Vec<DrafterKind>,
    /// Per-slot speculation policy: `fixed` pins every slot to the
    /// portfolio's primary drafter (default, byte-compatible), `auto`
    /// re-selects per slot from the acceptance EWMA with hysteresis,
    /// `off` disables speculation entirely (see `adapt::SpecPolicy`).
    pub spec_mode: SpecMode,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Vanilla,
    Medusa,
    Hydra,
    Ctc,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "vanilla" => Method::Vanilla,
            "medusa" => Method::Medusa,
            "hydra" => Method::Hydra,
            "ctc" => Method::Ctc,
            other => bail!("unknown method '{other}' (vanilla|medusa|hydra|ctc)"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            Method::Vanilla => "vanilla",
            Method::Medusa => "medusa",
            Method::Hydra => "hydra",
            Method::Ctc => "ctc",
        }
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "vic-tiny".into(),
            method: Method::Ctc,
            slot_topk: 5,
            max_paths: 16,
            ctc_transform: true,
            max_new_tokens: 128,
            temperature: 0.0,
            seed: 0,
            kv_pool_positions: 0,
            queue_cap: 0,
            slo: SloPolicy::default(),
            beta_policy: BetaPolicy::Fixed,
            drafter_portfolio: Vec::new(),
            spec_mode: SpecMode::Fixed,
        }
    }
}

/// Event-driven server frontend knobs (`server.rs` connection drivers).
#[derive(Debug, Clone)]
pub struct FrontendConfig {
    /// Connection-driver threads; 0 = one per available core.
    pub io_threads: usize,
    /// Bounded per-connection write queue, in frames. A connection whose
    /// queue would exceed this (its reader stalled while frames kept
    /// arriving) is SHED: closed, cancelled, counted in `conn.shed`.
    pub conn_write_cap: usize,
    /// Open-connection ceiling across all drivers; accepts past it are
    /// rejected with a terminal `busy` frame and closed.
    pub max_conns: usize,
    /// Graceful-drain budget for `Server::stop()`: drivers keep relaying
    /// in-flight frames and flushing write queues this long, then force-
    /// close whatever is left.
    pub drain_deadline_ms: u64,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            io_threads: 0,
            conn_write_cap: 256,
            max_conns: 4096,
            drain_deadline_ms: 5000,
        }
    }
}

/// Worker supervision + fault-tolerance knobs (`supervisor.rs`): panic
/// isolation with capped-backoff restarts, the round watchdog, and the
/// router's failover retry budget. Timings here are wall-clock for the
/// real server; the sim uses virtual-step analogues so replays stay
/// byte-for-byte deterministic.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// run worker loops under `catch_unwind` with supervised restarts; off
    /// reproduces the legacy die-with-the-process behavior
    pub enabled: bool,
    /// restart backoff base (ms), doubled per consecutive restart
    pub backoff_base_ms: u64,
    /// restart backoff cap (ms)
    pub backoff_cap_ms: u64,
    /// round watchdog: wall ms a busy worker's heartbeat may stagnate
    /// before it is condemned like a crash; 0 disables the watchdog
    pub watchdog_ms: u64,
    /// failover budget: times one generate may be resubmitted to a
    /// surviving worker after its worker crashed (client sees `retrying`)
    pub retry_budget: u32,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            enabled: true,
            backoff_base_ms: 50,
            backoff_cap_ms: 2_000,
            watchdog_ms: 0,
            retry_budget: 2,
        }
    }
}

/// Artifact-free serving: workers run a deterministic mock engine (token
/// streams are a pure function of the prompt, via `testkit::mock_tokens`)
/// instead of loading a Runtime. This is what the C10k/concurrency suite
/// and `ctcdraft connbench` drive: transport behavior at scale, with real
/// shared-pool accounting, and no artifacts directory required.
#[derive(Debug, Clone)]
pub struct MockServeConfig {
    /// batch slots per mock worker
    pub slots: usize,
    /// admit-queue bound (0 = unbounded)
    pub queue_cap: usize,
    /// shared KV pool positions, cluster-wide (granularity 1)
    pub pool_positions: usize,
    /// accepted tokens per sequence per round (a fixed mock β)
    pub beta: usize,
    /// per-round pacing sleep (µs); 0 = step as fast as possible
    pub step_delay_us: u64,
    /// seeded fault injection (`workload::FaultPlan::seeded`): mock
    /// workers panic/stall on schedule so supervision and failover are
    /// exercised over the real transport. None = no faults (default).
    pub fault_seed: Option<u64>,
}

impl Default for MockServeConfig {
    fn default() -> Self {
        MockServeConfig {
            slots: 64,
            queue_cap: 0,
            pool_positions: 1 << 16,
            beta: 4,
            step_delay_us: 500,
            fault_seed: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::Vanilla, Method::Medusa, Method::Hydra, Method::Ctc] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn manifest_loads_from_artifacts_if_present() {
        // integration-ish: only runs when artifacts/ exists
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.constants.vocab_size > 0);
        assert_eq!(m.constants.blank_id, m.constants.vocab_size);
        for (_name, meta) in &m.models {
            assert!(!meta.weight_order.is_empty());
            assert!(meta.graphs.contains_key("step_b1_n1"));
            assert!(meta.heads.contains_key("ctc"));
        }
    }

    #[test]
    fn pick_batch() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pick_batch(1), 1);
        assert_eq!(m.pick_batch(2), 4);
        assert_eq!(m.pick_batch(4), 4);
        assert_eq!(m.pick_batch(9), 4); // clamps to largest
    }
}
