//! PJRT runtime: loads AOT artifacts (`*.hlo.txt`) and executes them.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. HLO
//! *text* is the interchange format — xla_extension 0.5.1 rejects jax≥0.5
//! serialized protos (64-bit instruction ids).
//!
//! Executables are compiled lazily and cached; model/head weights are
//! converted to literals once at load.

pub mod tensor;
pub mod weights;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use anyhow::{anyhow, Result};

use crate::config::Manifest;
use tensor::Tensor;

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// graph file name -> compiled executable (lazy)
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// "model" or "model#head" -> ordered weight literals
    weights: RefCell<HashMap<String, Rc<Vec<xla::Literal>>>>,
    /// raw host copies of weights (kept for emb access by drafters/tests)
    host_weights: RefCell<HashMap<String, Rc<BTreeMap<String, Tensor>>>>,
    /// execution counters (perf accounting)
    pub stats: RefCell<RuntimeStats>,
    /// pinned-literal pool: reusable argument/staging scratch for the
    /// per-round graph calls (`run_step_pooled` / `run_draft_pooled`)
    pin: RefCell<LitPool>,
}

/// Reusable scratch for the XLA call boundary. Holds the argument
/// literals of the round in flight, the borrowed-pointer table handed to
/// PJRT (weights + args), and host staging buffers that callers pack
/// graph inputs into. All four keep their capacity across rounds, so a
/// steady-state step does no host `Vec` growth at the boundary — the only
/// remaining per-round cost is the one host→literal copy inside
/// `xla::Literal` construction, which the PJRT API owns.
#[derive(Default)]
pub struct LitPool {
    /// argument literals for the round in flight (cleared, capacity kept)
    args: Vec<xla::Literal>,
    /// borrowed-arg table for `execute` (weights first, then `args`)
    refs: Vec<*const xla::Literal>,
    stage_f32: Vec<f32>,
    stage_i32: Vec<i32>,
}

impl LitPool {
    /// Borrow the staging buffers at the requested lengths, grown (never
    /// shrunk) and reset to the padding values callers rely on (f32 rows
    /// zeroed, i32 slots zeroed). Steady state: no allocation.
    pub fn stage(&mut self, f32_len: usize, i32_len: usize)
                 -> (&mut [f32], &mut [i32]) {
        if self.stage_f32.len() < f32_len {
            self.stage_f32.resize(f32_len, 0.0);
        }
        if self.stage_i32.len() < i32_len {
            self.stage_i32.resize(i32_len, 0);
        }
        self.stage_f32[..f32_len].fill(0.0);
        self.stage_i32[..i32_len].fill(0);
        (&mut self.stage_f32[..f32_len], &mut self.stage_i32[..i32_len])
    }
}

#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compiles: u64,
    pub exec_secs: f64,
}

impl Runtime {
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            manifest,
            client,
            exes: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            host_weights: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
            pin: RefCell::new(LitPool::default()),
        })
    }

    // ------------------------------------------------------------ weights
    fn load_weight_list(&self, file: &str, order: &[String], key: &str)
                        -> Result<Rc<Vec<xla::Literal>>> {
        if let Some(w) = self.weights.borrow().get(key) {
            return Ok(w.clone());
        }
        let tensors = weights::read_tensors(self.manifest.dir.join(file))?;
        let mut lits = Vec::with_capacity(order.len());
        for name in order {
            let t = tensors
                .get(name)
                .ok_or_else(|| anyhow!("weights file {file} missing '{name}'"))?;
            lits.push(t.to_literal()?);
        }
        let rc = Rc::new(lits);
        self.weights.borrow_mut().insert(key.to_string(), rc.clone());
        self.host_weights
            .borrow_mut()
            .insert(key.to_string(), Rc::new(tensors));
        Ok(rc)
    }

    pub fn base_weights(&self, model: &str) -> Result<Rc<Vec<xla::Literal>>> {
        let meta = self.manifest.model(model)?;
        self.load_weight_list(&meta.weights_file, &meta.weight_order, model)
    }

    pub fn head_weights(&self, model: &str, head: &str) -> Result<Rc<Vec<xla::Literal>>> {
        let meta = self.manifest.model(model)?;
        let h = meta
            .heads
            .get(head)
            .ok_or_else(|| anyhow!("model {model} has no head '{head}'"))?;
        self.load_weight_list(&h.weights_file, &h.weight_order,
                              &format!("{model}#{head}"))
    }

    /// Total byte size of a loaded weight list (device-model accounting).
    pub fn weights_nbytes(&self, key: &str) -> usize {
        self.host_weights
            .borrow()
            .get(key)
            .map(|m| m.values().map(|t| t.len() * 4).sum())
            .unwrap_or(0)
    }

    /// Host copy of one base-model tensor (e.g. "emb").
    pub fn host_tensor(&self, model: &str, name: &str) -> Result<Tensor> {
        self.base_weights(model)?; // ensure loaded
        let hw = self.host_weights.borrow();
        let map = hw
            .get(model)
            .ok_or_else(|| anyhow!("weights for {model} not loaded"))?;
        map.get(name)
            .cloned()
            .ok_or_else(|| anyhow!("model {model} has no tensor '{name}'"))
    }

    // ------------------------------------------------------------ executables
    fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(file) {
            return Ok(e.clone());
        }
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        self.stats.borrow_mut().compiles += 1;
        let rc = Rc::new(exe);
        self.exes.borrow_mut().insert(file.to_string(), rc.clone());
        Ok(rc)
    }

    /// Force-compile every graph of a model (warmup; avoids first-request lag).
    pub fn warmup(&self, model: &str) -> Result<usize> {
        let files: Vec<String> = self
            .manifest
            .model(model)?
            .graphs
            .values()
            .map(|g| g.file.clone())
            .collect();
        let n = files.len();
        for f in files {
            self.executable(&f)?;
        }
        Ok(n)
    }

    fn execute(&self, file: &str, args: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let exe = self.executable(file)?;
        self.execute_prepared(file, &exe, args)
    }

    /// The shared tail of every graph call: run a compiled executable over
    /// an already-assembled borrowed-arg table, untuple, count. Does NOT
    /// touch `self.pin` — the pooled entry points hold its borrow across
    /// this call.
    fn execute_prepared(&self, file: &str, exe: &xla::PjRtLoadedExecutable,
                        args: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("executing {file}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {file}: {e:?}"))?;
        // graphs are lowered with return_tuple=True -> single tuple literal
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of {file}: {e:?}"))?;
        let mut out = Vec::with_capacity(parts.len());
        for p in &parts {
            out.push(Tensor::from_literal(p)?);
        }
        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.exec_secs += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// Run a base-model step graph: args = [kcache, vcache, tokens, pos, bias].
    pub fn run_step(&self, model: &str, batch: usize, n: usize,
                    args: &[Tensor]) -> Result<Vec<Tensor>> {
        let arg_lits: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_step_lits(model, batch, n, &arg_lits)
    }

    /// Literal-level variant of [`run_step`] — the engine hot path builds
    /// literals directly from reusable scratch buffers.
    pub fn run_step_lits(&self, model: &str, batch: usize, n: usize,
                         args: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let gname = format!("step_b{batch}_n{n}");
        let meta = self.manifest.model(model)?;
        let g = meta
            .graphs
            .get(&gname)
            .ok_or_else(|| anyhow!("model {model} has no graph {gname}"))?;
        let w = self.base_weights(model)?;
        let mut all: Vec<&xla::Literal> = w.iter().collect();
        all.extend(args.iter());
        self.execute(&g.file, &all)
    }

    /// Pool-backed twin of [`run_step_lits`] for the engine hot path.
    /// `build` packs the round's argument literals into the pinned pool's
    /// (cleared, capacity-retaining) args vec; the borrowed-arg table is
    /// likewise assembled in reusable scratch, so the call builds no fresh
    /// host `Vec`s per round.
    pub fn run_step_pooled<F>(&self, model: &str, batch: usize, n: usize,
                              build: F) -> Result<Vec<Tensor>>
    where
        F: FnOnce(&mut Vec<xla::Literal>) -> Result<()>,
    {
        let gname = format!("step_b{batch}_n{n}");
        let meta = self.manifest.model(model)?;
        let g = meta
            .graphs
            .get(&gname)
            .ok_or_else(|| anyhow!("model {model} has no graph {gname}"))?;
        let w = self.base_weights(model)?;
        let exe = self.executable(&g.file)?;
        let mut pin = self.pin.borrow_mut();
        let LitPool { args, refs, .. } = &mut *pin;
        args.clear();
        build(args)?;
        refs.clear();
        refs.extend(w.iter().map(|l| l as *const xla::Literal));
        refs.extend(args.iter().map(|l| l as *const xla::Literal));
        // SAFETY: `&xla::Literal` and `*const xla::Literal` share one
        // layout, and every pointer derives from a borrow (`w`, `args`)
        // that outlives the call below; `refs` is not mutated again until
        // the next round re-enters a pooled entry point.
        let borrowed: &[&xla::Literal] = unsafe {
            std::mem::transmute::<&[*const xla::Literal], &[&xla::Literal]>(
                refs.as_slice())
        };
        self.execute_prepared(&g.file, &exe, borrowed)
    }

    /// Run a draft-head graph. `head` ∈ {ctc, medusa, hydra}; extra args per
    /// manifest (window/hidden/base_tok...). The base `emb` is injected
    /// between head weights and runtime args, as the graphs expect.
    pub fn run_draft(&self, model: &str, head: &str, batch: usize,
                     args: &[Tensor]) -> Result<Vec<Tensor>> {
        let gname = format!("draft_{head}_b{batch}");
        let meta = self.manifest.model(model)?;
        let g = meta
            .graphs
            .get(&gname)
            .ok_or_else(|| anyhow!("model {model} has no graph {gname}"))?;
        let hw = self.head_weights(model, head)?;
        let bw = self.base_weights(model)?;
        // emb is weight_order[0] by construction; assert to be safe
        let emb_idx = meta
            .weight_order
            .iter()
            .position(|n| n == "emb")
            .ok_or_else(|| anyhow!("model {model} has no 'emb' weight"))?;
        let arg_lits: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let mut all: Vec<&xla::Literal> = hw.iter().collect();
        all.push(&bw[emb_idx]);
        all.extend(arg_lits.iter());
        self.execute(&g.file, &all)
    }

    /// Pool-backed twin of [`run_draft`] for the CTC drafter hot path.
    /// `build` receives the pool's cleared args vec plus its f32/i32
    /// staging buffers (see [`LitPool::stage`]-style reuse) and packs the
    /// head's runtime arguments; weight/emb refs and the borrowed-arg
    /// table come from reusable scratch.
    pub fn run_draft_pooled<F>(&self, model: &str, head: &str, batch: usize,
                               build: F) -> Result<Vec<Tensor>>
    where
        F: FnOnce(&mut Vec<xla::Literal>, &mut Vec<f32>, &mut Vec<i32>)
            -> Result<()>,
    {
        let gname = format!("draft_{head}_b{batch}");
        let meta = self.manifest.model(model)?;
        let g = meta
            .graphs
            .get(&gname)
            .ok_or_else(|| anyhow!("model {model} has no graph {gname}"))?;
        let hw = self.head_weights(model, head)?;
        let bw = self.base_weights(model)?;
        let emb_idx = meta
            .weight_order
            .iter()
            .position(|n| n == "emb")
            .ok_or_else(|| anyhow!("model {model} has no 'emb' weight"))?;
        let exe = self.executable(&g.file)?;
        let mut pin = self.pin.borrow_mut();
        let LitPool { args, refs, stage_f32, stage_i32 } = &mut *pin;
        args.clear();
        build(args, stage_f32, stage_i32)?;
        refs.clear();
        refs.extend(hw.iter().map(|l| l as *const xla::Literal));
        refs.push(&bw[emb_idx] as *const xla::Literal);
        refs.extend(args.iter().map(|l| l as *const xla::Literal));
        // SAFETY: see `run_step_pooled` — same layout + lifetime argument.
        let borrowed: &[&xla::Literal] = unsafe {
            std::mem::transmute::<&[*const xla::Literal], &[&xla::Literal]>(
                refs.as_slice())
        };
        self.execute_prepared(&g.file, &exe, borrowed)
    }

    /// Run a standalone kernel artifact (e.g. ctc_score_b16).
    pub fn run_kernel(&self, kernel: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let g = self
            .manifest
            .kernels
            .get(kernel)
            .ok_or_else(|| anyhow!("no kernel '{kernel}' in manifest"))?;
        let arg_lits: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = arg_lits.iter().collect();
        self.execute(&g.file, &refs)
    }

    pub fn has_model(&self, model: &str) -> bool {
        self.manifest.models.contains_key(model)
    }

    pub fn take_stats(&self) -> RuntimeStats {
        let mut s = self.stats.borrow_mut();
        let out = s.clone();
        *s = RuntimeStats::default();
        out
    }
}

// The xla wrapper types hold raw pointers and are not auto-Send. Every
// Runtime is owned by exactly one thread (engine workers construct their
// own), so there is deliberately NO Send/Sync impl here — the compiler
// enforces the ownership discipline.

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Runtime::load(dir).ok()
    }

    fn first_model(rt: &Runtime) -> String {
        rt.manifest.models.keys().next().unwrap().clone()
    }

    #[test]
    fn loads_weights() {
        let Some(rt) = runtime() else { return };
        let m = first_model(&rt);
        let w = rt.base_weights(&m).unwrap();
        assert!(!w.is_empty());
        // cached: second call returns the same Rc
        let w2 = rt.base_weights(&m).unwrap();
        assert!(Rc::ptr_eq(&w, &w2));
        for head in ["ctc", "medusa", "hydra"] {
            assert!(rt.head_weights(&m, head).is_ok(), "{head}");
        }
    }

    #[test]
    fn decode_step_executes() {
        let Some(rt) = runtime() else { return };
        let m = first_model(&rt);
        let c = &rt.manifest.constants;
        let cfg = &rt.manifest.model(&m).unwrap().config;
        let (l, h, dh) = (cfg.layers, cfg.n_heads, c.head_dim);
        let cache_shape = [l, 1, c.lmax, h, dh];
        let mut bias = vec![-1e9f32; c.lmax + 1];
        bias[c.lmax] = 0.0; // token attends to itself only
        let args = vec![
            Tensor::zeros_f32(&cache_shape),
            Tensor::zeros_f32(&cache_shape),
            Tensor::from_i32(&[1, 1], vec![5]),
            Tensor::from_i32(&[1, 1], vec![0]),
            Tensor::from_f32(&[1, 1, c.lmax + 1], bias),
        ];
        let out = rt.run_step(&m, 1, 1, &args).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].shape(), &[1, 1, c.vocab_size]);
        assert_eq!(out[1].shape(), &[l, 1, 1, h, dh]);
        assert_eq!(out[3].shape(), &[1, 1, cfg.d_model]);
        let logits = out[0].f32_data().unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ctc_draft_executes() {
        let Some(rt) = runtime() else { return };
        let m = first_model(&rt);
        let c = &rt.manifest.constants;
        let d = rt.manifest.model(&m).unwrap().config.d_model;
        let args = vec![
            Tensor::zeros_f32(&[1, c.hidden_win, d]),
            Tensor::from_i32(&[1], vec![1]),
        ];
        let out = rt.run_draft(&m, "ctc", 1, &args).unwrap();
        assert_eq!(out[0].shape(), &[1, c.draft_slots, c.vocab_size + 1]);
        // rows are log-distributions
        let lp = out[0].f32_data().unwrap();
        let row: f32 = lp[..c.vocab_size + 1].iter().map(|v| v.exp()).sum();
        assert!((row - 1.0).abs() < 1e-3, "sum {row}");
    }

    #[test]
    fn ctc_score_kernel_executes() {
        let Some(rt) = runtime() else { return };
        let c = rt.manifest.constants.clone();
        let b = c.ctc_score_batch;
        let vp1 = c.vocab_size + 1;
        // uniform log-probs
        let lp = vec![-(vp1 as f32).ln(); b * c.draft_slots * vp1];
        let args = vec![
            Tensor::from_f32(&[b, c.draft_slots, vp1], lp),
            Tensor::from_i32(&[b, c.ctc_target_u],
                             vec![3; b * c.ctc_target_u]),
            Tensor::from_i32(&[b], vec![1; b]),
        ];
        let kname = format!("ctc_score_b{b}");
        let out = rt.run_kernel(&kname, &args).unwrap();
        let nll = out[0].f32_data().unwrap();
        assert_eq!(nll.len(), b);
        assert!(nll.iter().all(|v| *v > 0.0 && v.is_finite()));
    }
}
