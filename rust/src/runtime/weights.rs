//! tensors.bin reader — the binary weight interchange written by
//! `python/compile/export.py` (see that file for the byte layout).

use std::collections::BTreeMap;

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::Tensor;

const MAGIC: &[u8; 4] = b"CTCW";

pub fn read_tensors(path: impl AsRef<Path>) -> Result<BTreeMap<String, Tensor>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_tensors(&bytes).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse_tensors(bytes: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    let mut r = Reader { b: bytes, pos: 0 };
    let magic = r.take(4)?;
    if magic != MAGIC {
        bail!("bad magic {magic:?}");
    }
    let version = r.u32()?;
    if version != 1 {
        bail!("unsupported tensors.bin version {version}");
    }
    let count = r.u32()? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = r.u16()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .context("tensor name not utf-8")?;
        let dtype = r.u8()?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let nbytes = r.u64()? as usize;
        let payload = r.take(nbytes)?;
        let numel: usize = shape.iter().product();
        let tensor = match dtype {
            0 => {
                if nbytes != numel * 4 {
                    bail!("tensor '{name}': payload {nbytes}B != shape {shape:?}");
                }
                let mut data = vec![0f32; numel];
                le_to_f32(payload, &mut data);
                Tensor::F32 { shape, data }
            }
            1 => {
                if nbytes != numel * 4 {
                    bail!("tensor '{name}': payload {nbytes}B != shape {shape:?}");
                }
                let mut data = vec![0i32; numel];
                le_to_i32(payload, &mut data);
                Tensor::I32 { shape, data }
            }
            other => bail!("tensor '{name}': unknown dtype code {other}"),
        };
        if out.insert(name.clone(), tensor).is_some() {
            bail!("duplicate tensor '{name}'");
        }
    }
    if r.pos != bytes.len() {
        bail!("trailing bytes after last tensor");
    }
    Ok(out)
}

fn le_to_f32(src: &[u8], dst: &mut [f32]) {
    for (i, chunk) in src.chunks_exact(4).enumerate() {
        dst[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
}

fn le_to_i32(src: &[u8], dst: &mut [i32]) {
    for (i, chunk) in src.chunks_exact(4).enumerate() {
        dst[i] = i32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated file at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }
    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }
}

/// Write tensors in the same format (used by tests for roundtripping and by
/// tools that re-export weights).
pub fn write_tensors(tensors: &BTreeMap<String, Tensor>, order: &[String]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(order.len() as u32).to_le_bytes());
    for name in order {
        let t = &tensors[name];
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        let (code, payload): (u8, Vec<u8>) = match t {
            Tensor::F32 { data, .. } => {
                (0, data.iter().flat_map(|v| v.to_le_bytes()).collect())
            }
            Tensor::I32 { data, .. } => {
                (1, data.iter().flat_map(|v| v.to_le_bytes()).collect())
            }
        };
        out.push(code);
        out.push(t.shape().len() as u8);
        for d in t.shape() {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&payload);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::from_f32(&[2, 2], vec![1.0, -2.5, 0.0, 4.0]));
        m.insert("b".into(), Tensor::from_i32(&[3], vec![7, -9, 2]));
        m.insert("scalar".into(), Tensor::from_f32(&[], vec![3.25]));
        m
    }

    #[test]
    fn roundtrip() {
        let m = sample();
        let order: Vec<String> = vec!["a".into(), "b".into(), "scalar".into()];
        let bytes = write_tensors(&m, &order);
        let back = parse_tensors(&bytes).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = write_tensors(&sample(), &["a".into(), "b".into(), "scalar".into()]);
        bytes[0] = b'X';
        assert!(parse_tensors(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = write_tensors(&sample(), &["a".into(), "b".into(), "scalar".into()]);
        for cut in [3, 10, bytes.len() - 1] {
            assert!(parse_tensors(&bytes[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = write_tensors(&sample(), &["a".into(), "b".into(), "scalar".into()]);
        bytes.push(0);
        assert!(parse_tensors(&bytes).is_err());
    }

    #[test]
    fn reads_real_artifact_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let f = dir.join("vic-tiny.tensors.bin");
        if !f.exists() {
            return;
        }
        let m = read_tensors(&f).unwrap();
        assert!(m.contains_key("emb"));
        let emb = &m["emb"];
        assert_eq!(emb.shape().len(), 2);
    }
}
