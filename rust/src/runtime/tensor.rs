//! Host-side tensors and conversions to/from `xla::Literal`.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn i32_data(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("expected i32 tensor"),
        }
    }

    /// Build an `xla::Literal` (host->device copy happens at execute time).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Row-major strides for this shape.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(self.shape())
    }
}

/// Build an f32 literal straight from a borrowed slice (hot path:
/// `create_from_shape_and_untyped_data` copies exactly once, vs the two
/// copies of `vec1(..).reshape(..)`).
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32, shape, bytes)?)
}

/// i32 twin of [`literal_f32`].
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32, shape, bytes)?)
}

pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Flat offset of a multi-index in a row-major tensor.
pub fn offset(shape: &[usize], idx: &[usize]) -> usize {
    debug_assert_eq!(shape.len(), idx.len());
    let strides = strides_of(shape);
    idx.iter().zip(&strides).map(|(i, s)| i * s).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_offset() {
        let shape = [2, 3, 4];
        assert_eq!(strides_of(&shape), vec![12, 4, 1]);
        assert_eq!(offset(&shape, &[1, 2, 3]), 12 + 8 + 3);
        assert_eq!(offset(&shape, &[0, 0, 0]), 0);
    }

    #[test]
    fn tensor_basics() {
        let t = Tensor::zeros_f32(&[2, 2]);
        assert_eq!(t.len(), 4);
        assert!(t.f32_data().is_ok());
        assert!(t.i32_data().is_err());
        let t2 = Tensor::from_i32(&[3], vec![1, 2, 3]);
        assert_eq!(t2.i32_data().unwrap(), &[1, 2, 3]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::from_i32(&[4], vec![7, -1, 0, 3]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }
}
