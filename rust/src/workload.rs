//! Evaluation workloads: MT-bench- and GSM8K-style question generators.
//!
//! These mirror the synthetic dialogue distribution the models were trained
//! on (`python/compile/corpus.py`) — same 8 MT-bench categories, same
//! template families, different seeds, so evaluation questions are unseen
//! but in-distribution. That substitution (DESIGN.md §2) is what lets the
//! paper's per-category acceptance-rate structure (Fig 2) reproduce.

use crate::sched::{Priority, TenantSpec, TokenBucket};
use crate::util::rng::Rng;

pub const CATEGORIES: [&str; 8] = [
    "writing", "roleplay", "reasoning", "math",
    "coding", "extraction", "stem", "humanities",
];

#[derive(Debug, Clone)]
pub struct Question {
    pub category: &'static str,
    pub text: String,
}

const TOPICS: [&str; 10] = ["the ocean", "a small village", "the night sky",
    "an old library", "a mountain trail", "the harvest season",
    "a river crossing", "the city market", "a winter storm", "an ancient map"];
const ROLES: [&str; 6] = ["a ship captain", "a museum guide", "a village doctor",
    "a night watchman", "a railway engineer", "a lighthouse keeper"];
const NAMES: [&str; 5] = ["Ada", "Bruno", "Clara", "Daniel", "Elena"];
const CITIES: [&str; 5] = ["Lisbon", "Oslo", "Kyoto", "Quito", "Cairo"];
const FNS: [&str; 5] = ["add", "double", "square", "negate", "half"];
const STEM_QS: [&str; 5] = ["Why is the sky blue?", "What causes tides?",
    "How do plants make food?", "What is an atom?", "Why do seasons change?"];
const HUM_QS: [&str; 4] = ["Who writes history?", "What is a myth?",
    "Why do cities form near rivers?", "What is a constitution?"];

pub fn gen_question(rng: &mut Rng, category: &'static str) -> Question {
    let text = match category {
        "writing" => format!("Write a short paragraph about {}.",
                             rng.choice(&TOPICS)),
        "roleplay" => format!("Pretend you are {}. Introduce yourself.",
                              rng.choice(&ROLES)),
        "reasoning" => {
            let (a, b) = (rng.range(2, 9), rng.range(2, 9));
            format!("If a box holds {a} red balls and {b} blue balls, \
                     how many balls are in the box?")
        }
        "math" => match rng.below(3) {
            0 => {
                let (x, y) = (rng.range(10, 99), rng.range(10, 99));
                format!("What is {x} + {y}?")
            }
            1 => {
                let (x, y) = (rng.range(2, 12), rng.range(2, 12));
                format!("What is {x} times {y}?")
            }
            _ => {
                let (n, p) = (rng.range(3, 9), rng.range(2, 9));
                format!("A farmer packs {} apples into boxes of {p}. \
                         How many boxes does he fill?", n * p)
            }
        },
        "coding" => format!("Write a python function named {}.",
                            rng.choice(&FNS)),
        "extraction" => {
            let (n, c, y) = (rng.choice(&NAMES), rng.choice(&CITIES),
                             rng.range(1990, 2020));
            format!("Extract the name, city and year from: '{n} moved to \
                     {c} in {y} to study music.'")
        }
        "stem" => rng.choice(&STEM_QS).to_string(),
        "humanities" => rng.choice(&HUM_QS).to_string(),
        other => panic!("unknown category {other}"),
    };
    Question { category, text }
}

/// MT-bench analog: `per_category` questions for each of the 8 categories
/// (paper: 80 questions, 10 per category).
pub fn mtbench(per_category: usize, seed: u64) -> Vec<Question> {
    let mut rng = Rng::new(seed ^ 0x4d54_4245);
    let mut qs = Vec::with_capacity(per_category * CATEGORIES.len());
    for cat in CATEGORIES {
        for _ in 0..per_category {
            qs.push(gen_question(&mut rng, cat));
        }
    }
    qs
}

/// GSM8K analog: grade-school math word problems with multi-step answers.
pub fn gsm8k(count: usize, seed: u64) -> Vec<Question> {
    let mut rng = Rng::new(seed ^ 0x4753_4d38);
    (0..count)
        .map(|_| {
            let q = match rng.below(3) {
                0 => {
                    let (a, b, c) = (rng.range(11, 60), rng.range(11, 60),
                                     rng.range(2, 9));
                    format!("A shop sold {a} apples in the morning and {b} \
                             in the afternoon, in bags of {c}. How many \
                             apples were sold?")
                }
                1 => {
                    let (n, p) = (rng.range(3, 12), rng.range(3, 9));
                    format!("A farmer packs {} apples into boxes of {p}. \
                             How many boxes does he fill?", n * p)
                }
                _ => {
                    let (x, y) = (rng.range(12, 99), rng.range(12, 99));
                    format!("What is {x} + {y}? Explain step by step.")
                }
            };
            Question { category: "math", text: q }
        })
        .collect()
}

/// One request in a replayable load trace: what to ask, how much to
/// generate, *when* it arrives on the scheduler's virtual clock, and its
/// SLO tags (priority class + relative deadline).
#[derive(Debug, Clone)]
pub struct TraceEntry {
    pub question: Question,
    pub max_new: usize,
    /// arrival time in scheduler steps (virtual clock, monotone)
    pub arrival_step: u64,
    /// priority class the request is submitted under
    pub class: Priority,
    /// relative deadline in scheduler steps; None = the class default
    pub deadline_steps: Option<u64>,
    /// tenant tag; None = the default tenant (pre-tenant behavior)
    pub tenant: Option<String>,
}

/// A recorded trace of timed requests — replayable load for the server
/// benchmarks and the deterministic scheduler simulation (`testkit`).
#[derive(Debug, Clone)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
}

impl Trace {
    /// Poisson arrival process: i.i.d. exponential interarrival gaps with
    /// `mean_gap_steps` mean (in scheduler steps), plus per-request
    /// generation-length jitter in [0.5, 1.5]×`max_new` (min 8). Entries
    /// keep the input question order; arrival steps are nondecreasing.
    pub fn poisson_with_rate(questions: Vec<Question>, max_new: usize,
                             mean_gap_steps: f64, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut clock = 0f64;
        let entries = questions
            .into_iter()
            .map(|q| {
                let jitter = (max_new as f64 * (0.5 + rng.f64())) as usize;
                // inverse-CDF exponential draw; f64() < 1 keeps ln finite
                let gap = -(1.0 - rng.f64()).ln() * mean_gap_steps.max(0.0);
                clock += gap;
                TraceEntry {
                    question: q,
                    max_new: jitter.max(8),
                    arrival_step: clock as u64,
                    class: Priority::Interactive,
                    deadline_steps: None,
                    tenant: None,
                }
            })
            .collect();
        Trace { entries }
    }

    /// Back-compat shape: Poisson arrivals with a mean gap of 2 steps.
    pub fn poisson_arrivals(questions: Vec<Question>, max_new: usize,
                            seed: u64) -> Trace {
        Self::poisson_with_rate(questions, max_new, 2.0, seed)
    }

    /// Class-tagged Poisson arrivals: each request is `batch` with
    /// probability `batch_frac` (relative deadline `batch_deadline`), else
    /// `interactive` (`interactive_deadline`). Deterministic in `seed`; the
    /// class draw is independent of the arrival-time draws so the same seed
    /// yields the same arrival schedule as `poisson_with_rate`.
    #[allow(clippy::too_many_arguments)]
    pub fn poisson_with_classes(questions: Vec<Question>, max_new: usize,
                                mean_gap_steps: f64, seed: u64,
                                batch_frac: f64, interactive_deadline: u64,
                                batch_deadline: u64) -> Trace {
        let mut trace =
            Self::poisson_with_rate(questions, max_new, mean_gap_steps, seed);
        let mut rng = Rng::new(seed ^ 0x5105_C1A5);
        for e in &mut trace.entries {
            if rng.bool(batch_frac) {
                e.class = Priority::Batch;
                e.deadline_steps = Some(batch_deadline);
            } else {
                e.class = Priority::Interactive;
                e.deadline_steps = Some(interactive_deadline);
            }
        }
        trace
    }

    /// Multi-turn conversation trace (PR 6): `convs` interleaved
    /// conversations of `turns` turns each. Turn t's prompt is the
    /// conversation's first t+1 questions concatenated, so each turn's
    /// prompt is a strict string prefix of the next — the shape prefix
    /// sharing exists for. Turns are spaced `TURN_GAP_STEPS` apart (the
    /// previous turn finishes and publishes its prefix into the index
    /// before the follow-up arrives) and conversations are staggered a few
    /// steps so the scheduler interleaves them; entries are stably sorted
    /// by arrival so `due()`'s prefix walk holds. All-interactive with a
    /// generous deadline (the trace measures cache reuse, not SLO
    /// pressure). Deterministic in `seed`.
    pub fn multiturn(convs: usize, turns: usize, max_new: usize, seed: u64)
                     -> Trace {
        const TURN_GAP_STEPS: u64 = 48;
        const CONV_STAGGER_STEPS: u64 = 5;
        let mut rng = Rng::new(seed ^ 0x4d55_4c54);
        let mut entries = Vec::with_capacity(convs * turns);
        for c in 0..convs {
            let cat = *rng.choice(&CATEGORIES);
            let mut history = String::new();
            for t in 0..turns {
                let q = gen_question(&mut rng, cat);
                if t > 0 {
                    history.push('\n');
                }
                history.push_str(&q.text);
                let jitter = (max_new as f64 * (0.5 + rng.f64())) as usize;
                entries.push(TraceEntry {
                    question: Question {
                        category: cat,
                        text: history.clone(),
                    },
                    max_new: jitter.max(8),
                    arrival_step: t as u64 * TURN_GAP_STEPS
                        + c as u64 * CONV_STAGGER_STEPS,
                    class: Priority::Interactive,
                    deadline_steps: Some(512),
                    tenant: None,
                });
            }
        }
        // interleave conversations on the shared clock; stable sort keeps
        // same-step entries in conversation order for replayability
        entries.sort_by_key(|e| e.arrival_step);
        Trace { entries }
    }

    /// Arrivals due at or before `step` that come after the first `taken`
    /// entries (entries are arrival-ordered, so this is a prefix walk).
    pub fn due(&self, taken: usize, step: u64) -> &[TraceEntry] {
        let mut end = taken;
        while end < self.entries.len() && self.entries[end].arrival_step <= step {
            end += 1;
        }
        &self.entries[taken..end]
    }

    /// Tag every entry with a tenant name.
    pub fn tagged(mut self, tenant: &str) -> Trace {
        for e in &mut self.entries {
            e.tenant = Some(tenant.to_string());
        }
        self
    }

    /// Merge several traces onto one shared arrival clock. The sort is
    /// stable, so same-step entries keep input-trace order and the merge is
    /// deterministic (the `due()` prefix-walk contract holds).
    pub fn merge(traces: Vec<Trace>) -> Trace {
        let mut entries: Vec<TraceEntry> =
            traces.into_iter().flat_map(|t| t.entries).collect();
        entries.sort_by_key(|e| e.arrival_step);
        Trace { entries }
    }
}

/// How a synthetic client consumes its token stream — the transport-side
/// counterpart of `Trace`'s arrival process. Used by the frontend
/// concurrency suite and the seeded shed-replay scenario to exercise the
/// bounded write queues with realistic misbehavior, not just well-behaved
/// streamers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientBehavior {
    /// Reads every frame promptly; its write queue never backs up.
    Streaming,
    /// Reads `read_frames` frames, then stops reading entirely — the
    /// stalled-reader case the shed path exists for.
    SlowReader { read_frames: usize },
    /// Reads `after_frames` frames, then cancels its request mid-stream.
    CancelStorm { after_frames: usize },
    /// Reads `drop_after` frames, then disconnects mid-stream (its request
    /// is cancelled like any vanished client), reconnects, and retries the
    /// same request from scratch — the client-side mirror of server-side
    /// failover. Exercises the shed/cancel reclamation path and the
    /// retry path together.
    Flaky { drop_after: usize },
}

impl ClientBehavior {
    pub fn name(&self) -> &'static str {
        match self {
            ClientBehavior::Streaming => "streaming",
            ClientBehavior::SlowReader { .. } => "slow_reader",
            ClientBehavior::CancelStorm { .. } => "cancel_storm",
            ClientBehavior::Flaky { .. } => "flaky",
        }
    }
}

/// Deterministic behavior assignment for `n` clients: roughly
/// `slow_frac` slow readers and `cancel_frac` cancel storms, the rest
/// well-behaved streamers, shuffled by `seed` so misbehavers are not
/// clustered at one end of the connection id space.
pub fn behavior_mix(n: usize, slow_frac: f64, cancel_frac: f64, seed: u64)
                    -> Vec<ClientBehavior> {
    behavior_mix_flaky(n, slow_frac, cancel_frac, 0.0, seed)
}

/// `behavior_mix` plus a `flaky_frac` share of mid-stream disconnect-and-
/// retry clients. With `flaky_frac == 0` the RNG draw order is identical
/// to `behavior_mix`, so existing seeded transcripts are byte-stable.
pub fn behavior_mix_flaky(n: usize, slow_frac: f64, cancel_frac: f64,
                          flaky_frac: f64, seed: u64) -> Vec<ClientBehavior> {
    let mut rng = Rng::new(seed ^ 0xBEAA_17ED);
    let slow = ((n as f64) * slow_frac).round() as usize;
    let cancel = (((n as f64) * cancel_frac).round() as usize)
        .min(n.saturating_sub(slow));
    let flaky = (((n as f64) * flaky_frac).round() as usize)
        .min(n.saturating_sub(slow + cancel));
    let mut mix = Vec::with_capacity(n);
    for _ in 0..slow {
        mix.push(ClientBehavior::SlowReader { read_frames: rng.below(4) });
    }
    for _ in 0..cancel {
        mix.push(ClientBehavior::CancelStorm { after_frames: 1 + rng.below(6) });
    }
    for _ in 0..flaky {
        mix.push(ClientBehavior::Flaky { drop_after: 1 + rng.below(4) });
    }
    while mix.len() < n {
        mix.push(ClientBehavior::Streaming);
    }
    rng.shuffle(&mut mix);
    mix
}

// ---------------------------------------------------------- fault plans

/// One injectable failure, scheduled at an exact virtual step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Worker panics mid-round: its engine unwinds, the supervisor must
    /// drain its lease + index and fail its requests over.
    WorkerPanic { worker: usize },
    /// Worker's step wedges for `steps` rounds: the round watchdog must
    /// condemn it exactly like a crash.
    StepStall { worker: usize, steps: u64 },
    /// Transient pool-exhaustion spike: `blocks` vanish from the shared
    /// pool for `hold_steps` rounds (feeds the degradation ladder).
    PoolSpike { blocks: usize, hold_steps: u64 },
    /// A client connection drops mid-stream: its request is cancelled
    /// (the sim's stand-in for a conn I/O error).
    ConnError,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::WorkerPanic { .. } => "panic",
            FaultKind::StepStall { .. } => "stall",
            FaultKind::PoolSpike { .. } => "pool_spike",
            FaultKind::ConnError => "conn_error",
        }
    }
}

/// A fault due at virtual step `step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub step: u64,
    pub kind: FaultKind,
}

/// A seeded, replayable schedule of faults on the scheduler's virtual step
/// clock — the failure-mode counterpart of `Trace`. Entries are step-
/// ordered so `due()` is the same prefix walk as `Trace::due`.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Seeded chaos schedule over `horizon` virtual steps against
    /// `workers` workers. Always contains at least one worker panic and
    /// one step stall (the chaos gate's contract), plus a seeded mix of
    /// pool spikes and connection errors. Deterministic in `seed`.
    pub fn seeded(seed: u64, workers: usize, horizon: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17_5EED);
        let workers = workers.max(1);
        let horizon = horizon.max(32);
        let jitter = |rng: &mut Rng, base: u64| {
            base + rng.below((horizon / 8).max(2) as usize) as u64
        };
        let mut events = vec![
            FaultEvent {
                step: jitter(&mut rng, horizon / 4),
                kind: FaultKind::WorkerPanic { worker: rng.below(workers) },
            },
            FaultEvent {
                step: jitter(&mut rng, horizon / 2),
                kind: FaultKind::StepStall {
                    worker: rng.below(workers),
                    steps: 3 + rng.below(4) as u64,
                },
            },
            FaultEvent {
                step: jitter(&mut rng, horizon / 8),
                kind: FaultKind::PoolSpike {
                    blocks: 8 + rng.below(25),
                    hold_steps: 4 + rng.below(8) as u64,
                },
            },
            FaultEvent {
                step: jitter(&mut rng, (horizon * 3) / 8),
                kind: FaultKind::ConnError,
            },
        ];
        if workers > 1 {
            // second panic on a multi-worker cluster so failover is
            // exercised in both directions
            events.push(FaultEvent {
                step: jitter(&mut rng, (horizon * 5) / 8),
                kind: FaultKind::WorkerPanic { worker: rng.below(workers) },
            });
        }
        events.sort_by_key(|e| e.step);
        FaultPlan { events }
    }

    /// Faults due at or before `step` after the first `taken` entries
    /// (step-ordered prefix walk, mirroring `Trace::due`).
    pub fn due(&self, taken: usize, step: u64) -> &[FaultEvent] {
        let mut end = taken;
        while end < self.events.len() && self.events[end].step <= step {
            end += 1;
        }
        &self.events[taken..end]
    }

    pub fn panics(&self) -> usize {
        self.events.iter()
            .filter(|e| matches!(e.kind, FaultKind::WorkerPanic { .. }))
            .count()
    }

    pub fn stalls(&self) -> usize {
        self.events.iter()
            .filter(|e| matches!(e.kind, FaultKind::StepStall { .. }))
            .count()
    }
}

// ----------------------------------------------------- scenario library

/// Names of every library scenario, runnable via
/// `ctcdraft sim --scenario <name>`.
pub const SCENARIOS: [&str; 5] =
    ["diurnal", "agentic", "longctx", "noisy_neighbor", "cancel_storm"];

/// A named, seeded, replayable load shape: the trace plus the tenant
/// policy and sim knobs it is meant to run under. Each library scenario is
/// deterministic in `seed` (per-scenario XORed sub-seeds, so scenarios
/// never share an RNG stream), which is what lets check.sh double-replay
/// them byte-for-byte.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub trace: Trace,
    /// tenant specs to install before replay (weights, buckets, pool caps)
    pub tenants: Vec<TenantSpec>,
    /// per-request mid-stream cancellation probability for the sim
    pub cancel_prob: f64,
}

/// Build a library scenario by name. `None` for unknown names.
pub fn scenario(name: &str, seed: u64) -> Option<Scenario> {
    match name {
        // Diurnal traffic: one web tenant alternating rush-hour bursts
        // (mean gap 0.8 steps) with quiet troughs (mean gap 5) — the shape
        // that punishes admission policies tuned to a flat arrival rate.
        "diurnal" => {
            let s = seed ^ 0xD158_AA77;
            let mut rng = Rng::new(s);
            let qs = mtbench(6, s);
            let mut clock = 0f64;
            let entries = qs
                .into_iter()
                .enumerate()
                .map(|(i, q)| {
                    let mean = if (i / 12) % 2 == 0 { 0.8 } else { 5.0 };
                    let gap = -(1.0 - rng.f64()).ln() * mean;
                    clock += gap;
                    let jitter = (16.0 * (0.5 + rng.f64())) as usize;
                    TraceEntry {
                        question: q,
                        max_new: jitter.max(8),
                        arrival_step: clock as u64,
                        class: Priority::Interactive,
                        deadline_steps: Some(192),
                        tenant: Some("web".into()),
                    }
                })
                .collect();
            Some(Scenario {
                name: "diurnal",
                trace: Trace { entries },
                tenants: vec![TenantSpec::open("web")],
                cancel_prob: 0.0,
            })
        }
        // Agentic loop: one tool-calling tenant firing many short
        // completions back-to-back, throttled by a modest token bucket —
        // sustained rate matters here, not burst.
        "agentic" => {
            let s = seed ^ 0xA6E4_7100;
            let mut trace = Trace::poisson_with_rate(
                gsm8k(60, s), 8, 0.5, s).tagged("agent");
            for e in &mut trace.entries {
                e.deadline_steps = Some(96);
            }
            Some(Scenario {
                name: "agentic",
                trace,
                tenants: vec![TenantSpec {
                    name: "agent".into(),
                    weight: 2,
                    bucket: TokenBucket::new(8, 2000),
                    pool_share_pm: 1000,
                }],
                cancel_prob: 0.0,
            })
        }
        // Long-context summarization: few, large, batch-class requests
        // from a pool-capped tenant — the KV-pressure shape.
        "longctx" => {
            let s = seed ^ 0x10C0_57E7;
            let mut rng = Rng::new(s);
            let qs = mtbench(4, s);
            let mut clock = 0f64;
            let entries = qs
                .chunks(2)
                .map(|pair| {
                    let text = pair
                        .iter()
                        .map(|q| q.text.as_str())
                        .collect::<Vec<_>>()
                        .join(" Then: ");
                    let gap = -(1.0 - rng.f64()).ln() * 8.0;
                    clock += gap;
                    TraceEntry {
                        question: Question {
                            category: "extraction",
                            text: format!("Summarize: {text}"),
                        },
                        max_new: 40,
                        arrival_step: clock as u64,
                        class: Priority::Batch,
                        deadline_steps: Some(1024),
                        tenant: Some("research".into()),
                    }
                })
                .collect();
            Some(Scenario {
                name: "longctx",
                trace: Trace { entries },
                tenants: vec![TenantSpec {
                    name: "research".into(),
                    weight: 1,
                    bucket: TokenBucket::unlimited(),
                    pool_share_pm: 700,
                }],
                cancel_prob: 0.0,
            })
        }
        // The isolation centerpiece: a flooding batch tenant (tight
        // bucket, pool cap, weight 1) against a steady interactive victim
        // (weight 4, unthrottled). The property test and the check.sh gate
        // assert the victim's miss rate stays bounded.
        "noisy_neighbor" => {
            let s = seed ^ 0x4015_EBAD;
            let mut victim = Trace::poisson_with_rate(
                mtbench(3, s), 12, 4.0, s).tagged("tenant-a");
            for e in &mut victim.entries {
                e.deadline_steps = Some(192);
            }
            let mut noisy = Trace::poisson_with_rate(
                gsm8k(80, s.wrapping_add(1)), 16, 0.25,
                s.wrapping_add(1)).tagged("noisy");
            for e in &mut noisy.entries {
                e.class = Priority::Batch;
                e.deadline_steps = Some(2048);
            }
            Some(Scenario {
                name: "noisy_neighbor",
                trace: Trace::merge(vec![victim, noisy]),
                tenants: vec![
                    TenantSpec {
                        name: "tenant-a".into(),
                        weight: 4,
                        bucket: TokenBucket::unlimited(),
                        pool_share_pm: 1000,
                    },
                    TenantSpec {
                        name: "noisy".into(),
                        weight: 1,
                        bucket: TokenBucket::new(4, 500),
                        pool_share_pm: 400,
                    },
                ],
                cancel_prob: 0.0,
            })
        }
        // Adversarial cancellation: an interactive flood where a third of
        // streams cancel mid-flight — exercises reclamation under churn.
        "cancel_storm" => {
            let s = seed ^ 0xCA4C_5702;
            let mut trace = Trace::poisson_with_rate(
                mtbench(6, s), 16, 0.75, s).tagged("flashy");
            for e in &mut trace.entries {
                e.deadline_steps = Some(128);
            }
            Some(Scenario {
                name: "cancel_storm",
                trace,
                tenants: vec![TenantSpec::open("flashy")],
                cancel_prob: 0.35,
            })
        }
        _ => None,
    }
}

// ------------------------------------------------- speculation workload

/// Mixed speculation workload for the drafter-portfolio policy: three
/// tenants whose names drive `testkit::mock_profile` — `copybot`
/// (prompt-echo output that rewards the lookup drafter), `chat` (model-
/// drafter friendly), and `rejector` (adversarial output that defeats
/// every drafter, where plain decode wins). Budgets are clamped into
/// [48, 64] tokens so the online selector has room to converge within one
/// sequence, and deadlines are generous — this trace measures speculation
/// quality, not SLO pressure. Standalone (NOT in `SCENARIOS`; the frozen
/// library list is gated by check.sh): run via
/// `ctcdraft sim --trace spec_mixed` or `ctcdraft specbench`.
pub fn spec_mixed(seed: u64) -> Trace {
    let s = seed ^ 0x5BEC_317E;
    let copy = Trace::poisson_with_rate(mtbench(2, s), 56, 3.0, s)
        .tagged("copybot");
    let chat = Trace::poisson_with_rate(
        mtbench(2, s.wrapping_add(1)), 56, 3.0, s.wrapping_add(1))
        .tagged("chat");
    let reject = Trace::poisson_with_rate(
        gsm8k(12, s.wrapping_add(2)), 56, 4.0, s.wrapping_add(2))
        .tagged("rejector");
    let mut trace = Trace::merge(vec![copy, chat, reject]);
    for e in &mut trace.entries {
        e.max_new = e.max_new.clamp(48, 64);
        e.deadline_steps = Some(4096);
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn behavior_mix_is_deterministic_with_requested_fractions() {
        let a = behavior_mix(40, 0.25, 0.10, 9);
        let b = behavior_mix(40, 0.25, 0.10, 9);
        assert_eq!(a, b);
        assert_eq!(a.len(), 40);
        let slow = a.iter().filter(|c| c.name() == "slow_reader").count();
        let cancel = a.iter().filter(|c| c.name() == "cancel_storm").count();
        assert_eq!(slow, 10);
        assert_eq!(cancel, 4);
        // shuffled: not all misbehavers clustered at the front
        assert!(a[..14].iter().any(|c| c.name() == "streaming"));
        assert_ne!(a, behavior_mix(40, 0.25, 0.10, 10));
    }

    #[test]
    fn behavior_mix_flaky_adds_retriers_without_shifting_legacy_mix() {
        // flaky_frac = 0 must be byte-identical to behavior_mix (the
        // shedreplay transcripts in check.sh depend on it)
        assert_eq!(behavior_mix_flaky(40, 0.25, 0.10, 0.0, 9),
                   behavior_mix(40, 0.25, 0.10, 9));
        let a = behavior_mix_flaky(40, 0.25, 0.10, 0.15, 9);
        let b = behavior_mix_flaky(40, 0.25, 0.10, 0.15, 9);
        assert_eq!(a, b);
        assert_eq!(a.iter().filter(|c| c.name() == "flaky").count(), 6);
        assert_eq!(a.iter().filter(|c| c.name() == "slow_reader").count(), 10);
        assert!(a.iter().all(|c| match c {
            ClientBehavior::Flaky { drop_after } => *drop_after >= 1,
            _ => true,
        }));
    }

    #[test]
    fn fault_plan_is_seeded_and_guarantees_panic_plus_stall() {
        let a = FaultPlan::seeded(7, 2, 256);
        let b = FaultPlan::seeded(7, 2, 256);
        assert_eq!(a.events, b.events);
        assert!(a.panics() >= 1, "chaos gate needs at least one panic");
        assert!(a.stalls() >= 1, "chaos gate needs at least one stall");
        assert!(a.events.windows(2).all(|w| w[0].step <= w[1].step),
                "events must be step-ordered for due()");
        assert_ne!(a.events, FaultPlan::seeded(8, 2, 256).events);
        // single-worker plans target worker 0 only
        let solo = FaultPlan::seeded(7, 1, 256);
        assert!(solo.events.iter().all(|e| match e.kind {
            FaultKind::WorkerPanic { worker }
            | FaultKind::StepStall { worker, .. } => worker == 0,
            _ => true,
        }));
    }

    #[test]
    fn fault_plan_due_walks_prefix() {
        let p = FaultPlan::seeded(3, 2, 128);
        let last = p.events.last().unwrap().step;
        assert_eq!(p.due(0, last).len(), p.events.len());
        assert!(p.due(p.events.len(), last + 50).is_empty());
        let mut taken = 0;
        for step in 0..=last {
            taken += p.due(taken, step).len();
        }
        assert_eq!(taken, p.events.len(), "stepwise walk visits every fault once");
    }

    #[test]
    fn mtbench_shape() {
        let qs = mtbench(10, 0);
        assert_eq!(qs.len(), 80);
        for cat in CATEGORIES {
            assert_eq!(qs.iter().filter(|q| q.category == cat).count(), 10);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = mtbench(3, 7);
        let b = mtbench(3, 7);
        assert!(a.iter().zip(&b).all(|(x, y)| x.text == y.text));
        let c = mtbench(3, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.text != y.text));
    }

    #[test]
    fn gsm8k_is_math() {
        let qs = gsm8k(20, 1);
        assert_eq!(qs.len(), 20);
        assert!(qs.iter().all(|q| q.category == "math"));
        // questions contain numbers
        assert!(qs.iter().all(|q| q.text.chars().any(|c| c.is_ascii_digit())));
    }

    #[test]
    fn questions_nonempty_all_categories() {
        let mut rng = Rng::new(5);
        for cat in CATEGORIES {
            let q = gen_question(&mut rng, cat);
            assert!(!q.text.is_empty());
        }
    }

    #[test]
    fn trace_lengths_bounded() {
        let t = Trace::poisson_arrivals(mtbench(2, 0), 64, 3);
        assert_eq!(t.entries.len(), 16);
        assert!(t.entries.iter().all(|e| e.max_new >= 8 && e.max_new <= 96));
    }

    #[test]
    fn trace_arrivals_monotone_and_seeded() {
        let a = Trace::poisson_with_rate(mtbench(2, 0), 32, 3.0, 7);
        let b = Trace::poisson_with_rate(mtbench(2, 0), 32, 3.0, 7);
        assert!(a.entries.windows(2)
            .all(|w| w[0].arrival_step <= w[1].arrival_step));
        assert!(a.entries.iter().zip(&b.entries).all(|(x, y)| {
            x.arrival_step == y.arrival_step && x.max_new == y.max_new
        }));
        let c = Trace::poisson_with_rate(mtbench(2, 0), 32, 3.0, 8);
        assert!(a.entries.iter().zip(&c.entries)
            .any(|(x, y)| x.arrival_step != y.arrival_step));
    }

    #[test]
    fn class_tagged_trace_is_seeded_and_mixed() {
        let mk = || Trace::poisson_with_classes(
            mtbench(2, 0), 32, 2.0, 9, 0.5, 16, 128);
        let (a, b) = (mk(), mk());
        assert!(a.entries.iter().zip(&b.entries).all(|(x, y)| {
            x.class == y.class && x.deadline_steps == y.deadline_steps
                && x.arrival_step == y.arrival_step
        }));
        let batch = a.entries.iter()
            .filter(|e| e.class == Priority::Batch).count();
        assert!(batch > 0 && batch < a.entries.len(),
                "batch_frac=0.5 should mix classes, got {batch}/16");
        for e in &a.entries {
            let want = if e.class == Priority::Batch { 128 } else { 16 };
            assert_eq!(e.deadline_steps, Some(want));
        }
        // the arrival schedule matches the untagged constructor (same seed)
        let plain = Trace::poisson_with_rate(mtbench(2, 0), 32, 2.0, 9);
        assert!(a.entries.iter().zip(&plain.entries)
            .all(|(x, y)| x.arrival_step == y.arrival_step));
    }

    #[test]
    fn multiturn_prompts_are_prefix_chains() {
        let t = Trace::multiturn(4, 3, 12, 7);
        assert_eq!(t.entries.len(), 12);
        // arrivals nondecreasing (due() contract)
        assert!(t.entries.windows(2)
            .all(|w| w[0].arrival_step <= w[1].arrival_step));
        // conversation c's turn t arrives at t*48 + c*5 (c < 10, so the
        // stagger offset uniquely identifies the conversation); within
        // each, every prompt must be a strict string prefix of the next
        for c in 0..4u64 {
            let mut turns: Vec<&TraceEntry> = t.entries.iter()
                .filter(|e| e.arrival_step >= c * 5
                    && (e.arrival_step - c * 5) % 48 == 0)
                .collect();
            turns.sort_by_key(|e| e.arrival_step);
            assert_eq!(turns.len(), 3);
            for w in turns.windows(2) {
                assert!(w[1].question.text.starts_with(&w[0].question.text));
                assert!(w[1].question.text.len() > w[0].question.text.len());
            }
        }
        // deterministic in seed
        let a = Trace::multiturn(4, 3, 12, 7);
        assert!(t.entries.iter().zip(&a.entries).all(|(x, y)| {
            x.question.text == y.question.text
                && x.arrival_step == y.arrival_step
                && x.max_new == y.max_new
        }));
        let b = Trace::multiturn(4, 3, 12, 8);
        assert!(t.entries.iter().zip(&b.entries)
            .any(|(x, y)| x.question.text != y.question.text));
    }

    #[test]
    fn trace_due_walks_prefix() {
        let t = Trace::poisson_with_rate(mtbench(1, 0), 16, 4.0, 1);
        let last = t.entries.last().unwrap().arrival_step;
        // everything is due by the last arrival step
        assert_eq!(t.due(0, last).len(), t.entries.len());
        // nothing new is due once all were taken
        assert!(t.due(t.entries.len(), last + 100).is_empty());
        // prefix walk: due(0, s) grows with s
        let mid = t.entries[t.entries.len() / 2].arrival_step;
        assert!(t.due(0, mid).len() <= t.entries.len());
        assert!(!t.due(0, mid).is_empty());
    }

    #[test]
    fn scenario_library_is_named_seeded_and_replayable() {
        for name in SCENARIOS {
            let a = scenario(name, 7).expect(name);
            let b = scenario(name, 7).expect(name);
            assert_eq!(a.name, name);
            assert!(!a.trace.entries.is_empty(), "{name}: empty trace");
            assert!(!a.tenants.is_empty(), "{name}: no tenant policy");
            // replayable: identical trace + tags from the same seed
            assert_eq!(a.trace.entries.len(), b.trace.entries.len());
            assert!(a.trace.entries.iter().zip(&b.trace.entries).all(|(x, y)| {
                x.arrival_step == y.arrival_step
                    && x.question.text == y.question.text
                    && x.max_new == y.max_new
                    && x.class == y.class
                    && x.tenant == y.tenant
            }), "{name}: double build diverged");
            // arrival-ordered (due() contract), every entry tenant-tagged
            assert!(a.trace.entries.windows(2)
                .all(|w| w[0].arrival_step <= w[1].arrival_step),
                "{name}: arrivals not monotone");
            assert!(a.trace.entries.iter().all(|e| e.tenant.is_some()),
                    "{name}: untagged entry");
            // a different seed moves the schedule
            let c = scenario(name, 8).expect(name);
            assert!(a.trace.entries.iter().zip(&c.trace.entries).any(|(x, y)| {
                x.arrival_step != y.arrival_step
                    || x.question.text != y.question.text
            }), "{name}: seed is ignored");
        }
        assert!(scenario("no_such_scenario", 7).is_none());
    }

    #[test]
    fn spec_mixed_covers_all_three_profiles_with_room_to_converge() {
        let a = spec_mixed(7);
        let b = spec_mixed(7);
        assert!(a.entries.iter().zip(&b.entries).all(|(x, y)| {
            x.arrival_step == y.arrival_step
                && x.question.text == y.question.text
                && x.max_new == y.max_new
                && x.tenant == y.tenant
        }), "spec_mixed double build diverged");
        assert!(a.entries.windows(2)
            .all(|w| w[0].arrival_step <= w[1].arrival_step));
        for t in ["copybot", "chat", "rejector"] {
            assert!(a.entries.iter()
                        .any(|e| e.tenant.as_deref() == Some(t)),
                    "missing tenant {t}");
        }
        // every sequence gets enough rounds for the selector's dwell
        // windows (rejection-heavy needs ~35 plain rounds to demote)
        assert!(a.entries.iter()
            .all(|e| (48..=64).contains(&e.max_new)));
        assert!(spec_mixed(8).entries.iter().zip(&a.entries)
            .any(|(x, y)| x.arrival_step != y.arrival_step
                || x.question.text != y.question.text));
        // not part of the frozen scenario library
        assert!(!SCENARIOS.contains(&"spec_mixed"));
    }

    #[test]
    fn noisy_neighbor_pits_a_throttled_flood_against_a_weighted_victim() {
        let s = scenario("noisy_neighbor", 11).unwrap();
        assert_eq!(s.tenants.len(), 2);
        let noisy = s.tenants.iter().find(|t| t.name == "noisy").unwrap();
        let victim = s.tenants.iter().find(|t| t.name == "tenant-a").unwrap();
        assert!(!noisy.bucket.is_unlimited(), "flood must be rate-limited");
        assert!(noisy.pool_share_pm < 1000, "flood must be pool-capped");
        assert!(victim.bucket.is_unlimited());
        assert!(victim.weight > noisy.weight);
        let n_noisy = s.trace.entries.iter()
            .filter(|e| e.tenant.as_deref() == Some("noisy")).count();
        let n_victim = s.trace.entries.iter()
            .filter(|e| e.tenant.as_deref() == Some("tenant-a")).count();
        assert!(n_noisy >= 3 * n_victim,
                "flood should dominate offered load: {n_noisy} vs {n_victim}");
        // cancel_storm is the only canceling scenario in the library
        assert!(scenario("cancel_storm", 11).unwrap().cancel_prob > 0.0);
        assert_eq!(s.cancel_prob, 0.0);
    }
}
