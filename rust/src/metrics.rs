//! Serving metrics: per-stage timing breakdown (Fig 3), latency histograms,
//! acceptance accounting (β), speedup reporting (γ), and the scheduler
//! event log (admission/eviction/completion) used by the deterministic
//! scheduler simulation.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::sched::Priority;

/// Wall-time split of a decoding run into the paper's Fig-3 stages.
#[derive(Debug, Default, Clone, Copy)]
pub struct StageBreakdown {
    /// base-model step-graph execution (prefill + verify/decode)
    pub base_model_secs: f64,
    /// draft-graph execution
    pub draft_secs: f64,
    /// CTC transform + candidate expansion + tree/mask building
    pub transform_secs: f64,
    /// everything else (acceptance walk, cache writes, bookkeeping)
    pub other_secs: f64,
}

impl StageBreakdown {
    pub fn total(&self) -> f64 {
        self.base_model_secs + self.draft_secs + self.transform_secs + self.other_secs
    }

    /// Percentages in Fig-3 order: (base model, draft model, ctc transform, others).
    pub fn percentages(&self) -> (f64, f64, f64, f64) {
        let t = self.total().max(1e-12);
        (
            100.0 * self.base_model_secs / t,
            100.0 * self.draft_secs / t,
            100.0 * self.transform_secs / t,
            100.0 * self.other_secs / t,
        )
    }

    pub fn add(&mut self, other: &StageBreakdown) {
        self.base_model_secs += other.base_model_secs;
        self.draft_secs += other.draft_secs;
        self.transform_secs += other.transform_secs;
        self.other_secs += other.other_secs;
    }
}

/// Calibrated accelerator roofline for paper-comparable speedups.
///
/// The PJRT CPU substrate is *compute-bound on one core*, so verifying a
/// 32-node tree costs ~32× a single-token step and wall-clock speculative
/// decoding cannot win there by construction. The paper's γ is measured on
/// GPUs where single-token decoding is **memory-bandwidth-bound** — verify
/// and decode cost almost the same. This model charges each graph call
/// `launch + max(bytes/BW, flops/TP)` with A100-class constants; β and all
/// host-side costs stay measured. DESIGN.md §2 documents the substitution;
/// benches report both γ_device (model) and γ_wall (raw CPU).
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    /// HBM bandwidth, GB/s
    pub hbm_gbps: f64,
    /// sustained matmul throughput, TFLOP/s
    pub tflops: f64,
    /// per-graph-call launch/dispatch overhead, seconds
    pub launch_secs: f64,
}

impl Default for DeviceModel {
    fn default() -> Self {
        // A100-40GB-ish: 1555 GB/s, ~150 TFLOP/s sustained fp16, 8us launch
        DeviceModel { hbm_gbps: 1555.0, tflops: 150.0, launch_secs: 8e-6 }
    }
}

impl DeviceModel {
    /// Modeled execution time of one graph call.
    pub fn graph_secs(&self, bytes_moved: f64, flops: f64) -> f64 {
        let t_mem = bytes_moved / (self.hbm_gbps * 1e9);
        let t_comp = flops / (self.tflops * 1e12);
        self.launch_secs + t_mem.max(t_comp)
    }
}

/// Log-bucketed histogram; buckets are powers of two of the recorded unit
/// (microseconds for latencies, raw counts for dimensionless quantities).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// bucket i covers [2^i, 2^(i+1)) units
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
    /// display suffix in reports: "us" for time, "" for dimensionless
    unit: &'static str,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { buckets: vec![0; 36], count: 0, sum_us: 0, max_us: 0, unit: "us" }
    }

    /// A histogram over a dimensionless quantity (steps, counts, depths) —
    /// identical bucketing, no unit suffix in reports.
    pub fn new_unitless() -> Self {
        Histogram { unit: "", ..Self::new() }
    }

    pub fn record_secs(&mut self, secs: f64) {
        self.record_us((secs * 1e6).max(0.0) as u64);
    }

    pub fn record_us(&mut self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[b] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        self.max_us
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }
}

/// Named counters + histograms + gauges registry for a serving process.
#[derive(Debug, Default)]
pub struct Metrics {
    pub counters: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, Histogram>,
    pub gauges: BTreeMap<String, f64>,
    pub breakdown: StageBreakdown,
}

impl Metrics {
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn observe_secs(&mut self, name: &str, secs: f64) {
        self.histograms
            .entry(name.to_string())
            .or_default()
            .record_secs(secs);
    }

    /// Record a raw (unit-agnostic) value into a histogram; used for
    /// dimensionless scheduler quantities like queue-wait steps.
    pub fn observe(&mut self, name: &str, value: u64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(Histogram::new_unitless)
            .record_us(value);
    }

    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Raise a gauge to `value` if it is higher than the current reading —
    /// high-water marks (e.g. `conn.write_q_hwm`) without a separate type.
    pub fn set_gauge_max(&mut self, name: &str, value: f64) {
        let e = self.gauges.entry(name.to_string()).or_insert(f64::MIN);
        if value > *e {
            *e = value;
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            s.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in &self.gauges {
            s.push_str(&format!("{k}: {v:.3}\n"));
        }
        for (k, h) in &self.histograms {
            let u = h.unit;
            s.push_str(&format!(
                "{k}: n={} mean={:.1}{u} p50={}{u} p95={}{u} max={}{u}\n",
                h.count(),
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.95),
                h.max_us()
            ));
        }
        let (bm, dr, tr, ot) = self.breakdown.percentages();
        s.push_str(&format!(
            "breakdown: base={bm:.1}% draft={dr:.1}% transform={tr:.1}% other={ot:.1}%\n"
        ));
        s
    }
}

// ------------------------------------------------------ frontend gauges

/// Connection-frontend gauges shared (lock-free) between the acceptor and
/// every connection-driver thread of the event-driven server frontend.
/// `Metrics` itself is single-owner (each engine worker holds its own);
/// these counters cross threads, so they live in atomics and export into a
/// `Metrics` registry — or the `stats` op — as the `conn.*` gauge family:
/// `conn.open`, `conn.accepted`, `conn.shed`, `conn.rejected_max_conns`,
/// `conn.write_q_hwm`.
#[derive(Debug, Default)]
pub struct ConnGauges {
    /// connections currently registered with a driver
    open: AtomicU64,
    /// connections accepted since start (monotonic)
    accepted: AtomicU64,
    /// slow/stalled readers shed (write queue overflowed its cap)
    shed: AtomicU64,
    /// accepts rejected with `busy` because `--max-conns` was reached
    rejected_max_conns: AtomicU64,
    /// high-water mark of any connection's bounded write-queue depth
    write_q_hwm: AtomicU64,
}

impl ConnGauges {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_accept(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
        self.open.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_close(&self) {
        self.open.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_reject(&self) {
        self.rejected_max_conns.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a write-queue depth observation (keeps the max).
    pub fn note_write_q(&self, depth: usize) {
        self.write_q_hwm.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn open(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
    pub fn rejected_max_conns(&self) -> u64 {
        self.rejected_max_conns.load(Ordering::Relaxed)
    }
    pub fn write_q_hwm(&self) -> u64 {
        self.write_q_hwm.load(Ordering::Relaxed)
    }

    /// The canonical `conn.*` gauge family, for the `stats` op and for
    /// exporting into a `Metrics` registry.
    pub fn snapshot(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("conn.open", self.open() as f64),
            ("conn.accepted", self.accepted() as f64),
            ("conn.shed", self.shed() as f64),
            ("conn.rejected_max_conns", self.rejected_max_conns() as f64),
            ("conn.write_q_hwm", self.write_q_hwm() as f64),
        ]
    }

    pub fn export_into(&self, m: &mut Metrics) {
        for (name, v) in self.snapshot() {
            if name == "conn.write_q_hwm" {
                m.set_gauge_max(name, v);
            } else {
                m.set_gauge(name, v);
            }
        }
    }
}

// ------------------------------------------------------ scheduler events

/// One scheduler decision, stamped with the engine's step counter (a virtual
/// clock) rather than wall time, so event logs replay byte-for-byte from a
/// seed regardless of host speed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SchedEvent {
    /// Request entered the engine (either straight into a slot or queued)
    /// with its priority class and absolute deadline (virtual steps).
    Submitted { step: u64, id: u64, class: Priority, deadline: u64 },
    /// Request parked in the wait queue at admission-priority position
    /// `pos` (0 = next up under the current policy order).
    Queued { step: u64, id: u64, pos: usize },
    /// Request occupies a batch slot after `waited` steps in the queue.
    Admitted { step: u64, id: u64, waited: u64 },
    /// Request preempted mid-flight (KV pool pressure or deadline-driven
    /// preemption); it re-queues and will re-prefill its prompt + accepted
    /// tokens when re-admitted.
    Evicted { step: u64, id: u64, gen_len: usize },
    /// Request cancelled by the client; slot and pool blocks freed.
    Cancelled { step: u64, id: u64 },
    /// A resumable-prefill chunk ran this round: `done` of `total` prompt
    /// tokens are now prefilled (interleaved with decode rounds).
    Prefill { step: u64, id: u64, done: usize, total: usize },
    /// Request finished `late` steps past its deadline (SLO miss).
    DeadlineMiss { step: u64, id: u64, late: u64 },
    /// The β controller changed the per-round draft budget: decode batch
    /// size it reacted to, beam width (`paths`), tree-node budget and
    /// candidate depth. Logged only on change, so adaptive replays stay
    /// auditable without flooding the log.
    Beta { step: u64, batch: usize, paths: usize, nodes: usize, depth: usize },
    /// Request finished; `steps`/`tokens` feed the β histogram.
    Completed { step: u64, id: u64, steps: usize, tokens: usize },
    /// Router placement decision: request `id` routed to `worker` (shared-
    /// pool clusters only; id 0 = rejected before an id was assigned).
    Placed { step: u64, id: u64, worker: usize },
    /// Admission mapped a cached prompt prefix: `blocks` full KV blocks
    /// reused from the prefix index plus `fork` positions copied out of a
    /// diverging block (copy-on-write). Logged only on a hit, so cold
    /// traffic does not flood the log; replays make reuse auditable.
    Prefix { step: u64, id: u64, blocks: usize, fork: usize },
    /// A fault fired on `worker` (injected by a seeded `FaultPlan`, or a
    /// real panic/stall detected by the supervisor): `kind` is one of
    /// `panic`, `stall`, `pool_spike`, `conn_error`.
    Fault { step: u64, worker: usize, kind: &'static str },
    /// The supervisor recovered `worker` after a crash/condemnation:
    /// `requeued` in-flight requests were collected for failover and
    /// `freed` blocks (lease + index-owned) returned to the shared pool.
    Recover { step: u64, worker: usize, requeued: usize, freed: usize },
    /// Request `id` was resubmitted from crashed worker `from` to healthy
    /// worker `to`, replaying from the prompt.
    Failover { step: u64, id: u64, from: usize, to: usize },
    /// The degradation ladder moved `worker` to a new rung (`healthy`,
    /// `no-spec`, `admit-pause`, `shed`), driven by pool pressure and the
    /// deadline-miss rate; deterministic in sim replays.
    Degrade { step: u64, worker: usize, rung: &'static str },
    /// A PER-TENANT degradation ladder moved tenant `tenant` to a new rung
    /// on `worker`, driven by that tenant's pool-share utilization and
    /// deadline misses — the over-budget tenant degrades alone (no-spec,
    /// then admit-pause) before the cluster-wide ladder has to move.
    Tenant { step: u64, worker: usize, tenant: String, rung: &'static str },
    /// The per-slot speculation policy switched sequence `id`'s drafter
    /// (`from` → `to`, `DrafterKind` names). Logged only on an actual
    /// switch, so `--spec-policy auto` replays stay auditable without
    /// flooding the log; the selection is pure arithmetic on accepted-token
    /// counts, so the switch sequence is byte-deterministic.
    DrafterSwitch { step: u64, id: u64, from: &'static str, to: &'static str },
}

impl fmt::Display for SchedEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SchedEvent::Submitted { step, id, class, deadline } => {
                write!(f, "t={step} submit id={id} class={} deadline={deadline}",
                       class.name())
            }
            SchedEvent::Queued { step, id, pos } => {
                write!(f, "t={step} queue id={id} pos={pos}")
            }
            SchedEvent::Admitted { step, id, waited } => {
                write!(f, "t={step} admit id={id} waited={waited}")
            }
            SchedEvent::Evicted { step, id, gen_len } => {
                write!(f, "t={step} evict id={id} gen={gen_len}")
            }
            SchedEvent::Cancelled { step, id } => {
                write!(f, "t={step} cancel id={id}")
            }
            SchedEvent::Prefill { step, id, done, total } => {
                write!(f, "t={step} prefill id={id} done={done}/{total}")
            }
            SchedEvent::DeadlineMiss { step, id, late } => {
                write!(f, "t={step} deadline-miss id={id} late={late}")
            }
            SchedEvent::Beta { step, batch, paths, nodes, depth } => {
                write!(f, "t={step} beta batch={batch} paths={paths} \
                           nodes={nodes} depth={depth}")
            }
            SchedEvent::Completed { step, id, steps, tokens } => {
                write!(f, "t={step} done id={id} steps={steps} tokens={tokens}")
            }
            SchedEvent::Placed { step, id, worker } => {
                write!(f, "t={step} place id={id} worker={worker}")
            }
            SchedEvent::Prefix { step, id, blocks, fork } => {
                write!(f, "t={step} prefix id={id} blocks={blocks} fork={fork}")
            }
            SchedEvent::Fault { step, worker, kind } => {
                write!(f, "t={step} fault worker={worker} kind={kind}")
            }
            SchedEvent::Recover { step, worker, requeued, freed } => {
                write!(f, "t={step} recover worker={worker} \
                           requeued={requeued} freed={freed}")
            }
            SchedEvent::Failover { step, id, from, to } => {
                write!(f, "t={step} failover id={id} from={from} to={to}")
            }
            SchedEvent::Degrade { step, worker, rung } => {
                write!(f, "t={step} degrade worker={worker} rung={rung}")
            }
            SchedEvent::Tenant { step, worker, tenant, rung } => {
                write!(f, "t={step} tenant-degrade name={tenant} \
                           worker={worker} rung={rung}")
            }
            SchedEvent::DrafterSwitch { step, id, from, to } => {
                write!(f, "t={step} drafter-switch id={id} from={from} to={to}")
            }
        }
    }
}

/// Retention cap for `EventLog::default()` — far above any simulation run
/// (the determinism tests compare complete logs), but bounded so a
/// long-running server worker does not grow its heap without limit.
pub const EVENT_LOG_DEFAULT_CAP: usize = 65_536;

/// Scheduler event log. `render()` is the canonical byte-for-byte
/// representation compared by the determinism tests. Retention is bounded:
/// once `cap` events are held, the oldest half is discarded (counted in
/// `dropped`), so sustained serving traffic cannot leak memory.
#[derive(Debug)]
pub struct EventLog {
    events: Vec<SchedEvent>,
    /// 0 = unbounded
    cap: usize,
    dropped: u64,
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog { events: Vec::new(), cap: EVENT_LOG_DEFAULT_CAP, dropped: 0 }
    }
}

impl EventLog {
    pub fn with_cap(cap: usize) -> Self {
        EventLog { cap, ..Self::default() }
    }

    pub fn push(&mut self, e: SchedEvent) {
        if self.cap > 0 && self.events.len() >= self.cap {
            let n = (self.cap / 2).max(1);
            self.events.drain(..n);
            self.dropped += n as u64;
        }
        self.events.push(e);
    }

    /// Events discarded so far under the retention cap.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn events(&self) -> &[SchedEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn clear(&mut self) {
        self.events.clear();
        self.dropped = 0;
    }

    /// One line per event, in order.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for e in &self.events {
            s.push_str(&format!("{e}\n"));
        }
        s
    }
}

/// Paper metrics for one evaluated run (a set of questions, one method).
#[derive(Debug, Clone, Default)]
pub struct RunSummary {
    pub total_tokens: usize,
    pub total_steps: usize,
    /// measured wall time on this substrate (1-core CPU PJRT)
    pub total_secs: f64,
    /// modeled accelerator time (DeviceModel); 0 when not tracked
    pub device_secs: f64,
    pub breakdown: StageBreakdown,
}

impl RunSummary {
    /// β — average tokens accepted per base-model decoding step (Eq. 12).
    pub fn beta(&self) -> f64 {
        if self.total_steps == 0 {
            0.0
        } else {
            self.total_tokens as f64 / self.total_steps as f64
        }
    }

    /// tokens per second over the whole run.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.total_secs <= 0.0 {
            0.0
        } else {
            self.total_tokens as f64 / self.total_secs
        }
    }

    /// γ — speedup vs a vanilla run (Eq. 13: ratio of per-token times) on
    /// the modeled device when both runs tracked it, else on wall time.
    pub fn gamma_vs(&self, vanilla: &RunSummary) -> f64 {
        if self.device_secs > 0.0 && vanilla.device_secs > 0.0 {
            let spec = self.device_secs / self.total_tokens.max(1) as f64;
            let van = vanilla.device_secs / vanilla.total_tokens.max(1) as f64;
            return if spec <= 0.0 { 0.0 } else { van / spec };
        }
        self.gamma_wall_vs(vanilla)
    }

    /// γ measured on raw wall-clock of this substrate (compute-bound CPU —
    /// expected < 1 for tree verification; see DeviceModel docs).
    pub fn gamma_wall_vs(&self, vanilla: &RunSummary) -> f64 {
        let spec = self.total_secs / self.total_tokens.max(1) as f64;
        let van = vanilla.total_secs / vanilla.total_tokens.max(1) as f64;
        if spec <= 0.0 {
            0.0
        } else {
            van / spec
        }
    }

    pub fn merge(&mut self, other: &RunSummary) {
        self.total_tokens += other.total_tokens;
        self.total_steps += other.total_steps;
        self.total_secs += other.total_secs;
        self.device_secs += other.device_secs;
        self.breakdown.add(&other.breakdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conn_gauges_track_lifecycle_and_hwm() {
        let g = ConnGauges::new();
        g.on_accept();
        g.on_accept();
        g.on_close();
        g.on_shed();
        g.on_reject();
        g.note_write_q(3);
        g.note_write_q(9);
        g.note_write_q(5);
        assert_eq!(g.open(), 1);
        assert_eq!(g.accepted(), 2);
        assert_eq!(g.shed(), 1);
        assert_eq!(g.rejected_max_conns(), 1);
        assert_eq!(g.write_q_hwm(), 9, "hwm keeps the max observation");
        let mut m = Metrics::default();
        g.export_into(&mut m);
        assert_eq!(m.gauge("conn.open"), 1.0);
        assert_eq!(m.gauge("conn.write_q_hwm"), 9.0);
        // hwm gauge never regresses even if a later snapshot reads lower
        m.set_gauge_max("conn.write_q_hwm", 4.0);
        assert_eq!(m.gauge("conn.write_q_hwm"), 9.0);
    }

    #[test]
    fn breakdown_percentages_sum_to_100() {
        let b = StageBreakdown {
            base_model_secs: 0.7,
            draft_secs: 0.15,
            transform_secs: 0.05,
            other_secs: 0.1,
        };
        let (a, d, t, o) = b.percentages();
        assert!((a + d + t + o - 100.0).abs() < 1e-9);
        assert!((a - 70.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..20 {
                h.record_us(us);
            }
        }
        assert_eq!(h.count(), 100);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.95));
        assert!(h.quantile_us(0.95) <= h.quantile_us(1.0).max(h.max_us()));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn run_summary_beta_gamma() {
        let vanilla = RunSummary { total_tokens: 100, total_steps: 100, total_secs: 10.0, ..Default::default() };
        let spec = RunSummary { total_tokens: 100, total_steps: 30, total_secs: 4.0, ..Default::default() };
        assert!((spec.beta() - 100.0 / 30.0).abs() < 1e-9);
        assert!((vanilla.beta() - 1.0).abs() < 1e-9);
        // vanilla: 0.1 s/tok; spec: 0.04 s/tok -> gamma 2.5
        assert!((spec.gamma_vs(&vanilla) - 2.5).abs() < 1e-9);
        assert!((vanilla.gamma_vs(&vanilla) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_report_contains_entries() {
        let mut m = Metrics::default();
        m.inc("requests", 3);
        m.observe_secs("step", 0.01);
        m.set_gauge("queue_depth", 2.0);
        let r = m.report();
        assert!(r.contains("requests: 3"));
        assert!(r.contains("step:"));
        assert!(r.contains("breakdown:"));
        assert!(r.contains("queue_depth: 2.000"));
        assert_eq!(m.counter("requests"), 3);
        assert_eq!(m.counter("missing"), 0);
        assert!((m.gauge("queue_depth") - 2.0).abs() < 1e-12);
    }

    #[test]
    fn event_log_cap_bounds_memory() {
        let mut log = EventLog::with_cap(8);
        for i in 0..100 {
            log.push(SchedEvent::Submitted {
                step: i,
                id: i,
                class: Priority::Interactive,
                deadline: i + 8,
            });
        }
        assert!(log.len() <= 8, "cap not enforced: {}", log.len());
        assert_eq!(log.dropped() + log.len() as u64, 100);
        // the newest event is always retained
        assert!(log.render().contains("t=99 submit id=99"));
        log.clear();
        assert_eq!(log.dropped(), 0);
        assert!(log.is_empty());
    }

    #[test]
    fn event_log_renders_deterministically() {
        let mk = || {
            let mut log = EventLog::default();
            log.push(SchedEvent::Submitted {
                step: 1, id: 1, class: Priority::Batch, deadline: 65,
            });
            log.push(SchedEvent::Queued { step: 1, id: 2, pos: 0 });
            log.push(SchedEvent::Admitted { step: 2, id: 2, waited: 1 });
            log.push(SchedEvent::Prefill { step: 2, id: 2, done: 32, total: 96 });
            log.push(SchedEvent::Evicted { step: 3, id: 2, gen_len: 4 });
            log.push(SchedEvent::Cancelled { step: 4, id: 1 });
            log.push(SchedEvent::Beta {
                step: 4, batch: 2, paths: 8, nodes: 16, depth: 5,
            });
            log.push(SchedEvent::DeadlineMiss { step: 5, id: 2, late: 3 });
            log.push(SchedEvent::Completed { step: 5, id: 2, steps: 3, tokens: 7 });
            log.push(SchedEvent::Placed { step: 6, id: 3, worker: 1 });
            log.push(SchedEvent::Prefix { step: 6, id: 3, blocks: 2, fork: 5 });
            log.push(SchedEvent::Fault { step: 7, worker: 0, kind: "panic" });
            log.push(SchedEvent::Recover {
                step: 8, worker: 0, requeued: 2, freed: 12,
            });
            log.push(SchedEvent::Failover { step: 8, id: 3, from: 0, to: 1 });
            log.push(SchedEvent::Degrade {
                step: 9, worker: 1, rung: "no-spec",
            });
            log.push(SchedEvent::Tenant {
                step: 10, worker: 1, tenant: "noisy".into(), rung: "admit-pause",
            });
            log.push(SchedEvent::DrafterSwitch {
                step: 11, id: 2, from: "ctc", to: "none",
            });
            log
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.render(), b.render());
        assert_eq!(a.len(), 17);
        assert!(a.render().contains("t=6 place id=3 worker=1"));
        assert!(a.render().contains("t=6 prefix id=3 blocks=2 fork=5"));
        assert!(a.render().contains("t=4 beta batch=2 paths=8 nodes=16 depth=5"));
        assert!(a.render().contains("t=1 submit id=1 class=batch deadline=65"));
        assert!(a.render().contains("t=2 admit id=2 waited=1"));
        assert!(a.render().contains("t=2 prefill id=2 done=32/96"));
        assert!(a.render().contains("t=5 deadline-miss id=2 late=3"));
        assert!(a.render().contains("t=5 done id=2 steps=3 tokens=7"));
        assert!(a.render().contains("t=7 fault worker=0 kind=panic"));
        assert!(a.render().contains("t=8 recover worker=0 requeued=2 freed=12"));
        assert!(a.render().contains("t=8 failover id=3 from=0 to=1"));
        assert!(a.render().contains("t=9 degrade worker=1 rung=no-spec"));
        assert!(a.render().contains(
            "t=10 tenant-degrade name=noisy worker=1 rung=admit-pause"));
        assert!(a.render().contains(
            "t=11 drafter-switch id=2 from=ctc to=none"));
    }
}
