//! Token-tree construction and tree-attention mask building.
//!
//! Candidate paths from a drafter are merged prefix-wise into a single tree
//! (node 0 = the base token, which greedy verification has already decided).
//! The tree is what the step graph verifies in one pass: node `i` may attend
//! to the KV cache plus its own ancestor chain — exactly the additive bias
//! this module builds. The paper's CTC Transform patches candidate content
//! *before* this tree is built (see `ctc::transform_paths`), so removed
//! blank/duplicate positions never appear in the attention map.
//!
//! Layout (PR 3): the tree is an **arena in SoA form** — flat `tokens` /
//! `parent` / `first_child` / `next_sibling` arrays plus a per-node ancestor
//! **bitset** (`anc_mask`) that is extended incrementally as nodes are
//! pushed (`mask[i] = mask[parent] | 1<<i`). A tree is `rebuild`-able in
//! place, so the engine's per-slot scratch tree performs zero heap
//! allocations in steady state; child lookup walks the sibling list instead
//! of scanning every node, and bias rows come straight off the bitset
//! instead of re-deriving ancestor chains.

use crate::drafters::CandidatePath;

pub const NEG_INF: f32 = -1e9;

/// Hard cap on nodes per tree — the ancestor bitset is one `u128` per node.
/// Far above any exported verify width (`tree_n` is 32 in the artifacts).
pub const MAX_TREE_NODES: usize = 128;

#[derive(Debug, Clone, Default)]
pub struct TokenTree {
    tokens: Vec<i32>,
    /// parent index; -1 for the root
    parent: Vec<i32>,
    depth: Vec<u32>,
    /// cumulative candidate score down to this node (root = 0)
    score: Vec<f32>,
    first_child: Vec<i32>,
    next_sibling: Vec<i32>,
    /// ancestor-or-self bitset: bit `j` set iff node `j` is on `i`'s chain
    anc_mask: Vec<u128>,
}

impl TokenTree {
    /// An empty arena (no root yet); `reset` before use.
    pub fn new() -> TokenTree {
        TokenTree::default()
    }

    /// Pre-sized arena so steady-state `rebuild` calls never reallocate.
    pub fn with_capacity(max_nodes: usize) -> TokenTree {
        let n = max_nodes.min(MAX_TREE_NODES).max(1);
        TokenTree {
            tokens: Vec::with_capacity(n),
            parent: Vec::with_capacity(n),
            depth: Vec::with_capacity(n),
            score: Vec::with_capacity(n),
            first_child: Vec::with_capacity(n),
            next_sibling: Vec::with_capacity(n),
            anc_mask: Vec::with_capacity(n),
        }
    }

    /// Only the base token — the degenerate tree used by vanilla decoding.
    pub fn root_only(base_token: i32) -> TokenTree {
        let mut t = TokenTree::with_capacity(1);
        t.reset(base_token);
        t
    }

    /// Clear the arena and install a fresh root (keeps capacity).
    pub fn reset(&mut self, base_token: i32) {
        self.tokens.clear();
        self.parent.clear();
        self.depth.clear();
        self.score.clear();
        self.first_child.clear();
        self.next_sibling.clear();
        self.anc_mask.clear();
        self.tokens.push(base_token);
        self.parent.push(-1);
        self.depth.push(0);
        self.score.push(0.0);
        self.first_child.push(-1);
        self.next_sibling.push(-1);
        self.anc_mask.push(1);
    }

    /// Append a child of `parent`; returns the new node index.
    fn push_child(&mut self, parent: usize, token: i32, score: f32) -> usize {
        let i = self.tokens.len();
        debug_assert!(i < MAX_TREE_NODES, "tree exceeds MAX_TREE_NODES");
        self.tokens.push(token);
        self.parent.push(parent as i32);
        self.depth.push(self.depth[parent] + 1);
        self.score.push(score);
        self.first_child.push(-1);
        self.next_sibling.push(self.first_child[parent]);
        self.first_child[parent] = i as i32;
        self.anc_mask.push(self.anc_mask[parent] | (1u128 << i));
        i
    }

    /// Child of `parent` carrying `token`, via the sibling list (no full
    /// node scan).
    fn find_child(&self, parent: usize, token: i32) -> Option<usize> {
        let mut c = self.first_child[parent];
        while c >= 0 {
            let ci = c as usize;
            if self.tokens[ci] == token {
                return Some(ci);
            }
            c = self.next_sibling[ci];
        }
        None
    }

    /// Rebuild the arena in place from candidate continuations (each a path
    /// *after* the base token), capped at `max_nodes` nodes. `paths` MUST be
    /// iterated in descending score order so the cap keeps the most valuable
    /// branches — "a group of the most valuable combinations are reserved"
    /// (paper §3.3).
    pub fn rebuild<'a, I>(&mut self, base_token: i32, paths: I, max_nodes: usize)
    where
        I: IntoIterator<Item = (&'a [i32], f32)>,
    {
        let max_nodes = max_nodes.min(MAX_TREE_NODES);
        self.reset(base_token);
        for (tokens, score) in paths {
            let mut cur = 0usize;
            for &tok in tokens {
                match self.find_child(cur, tok) {
                    Some(c) => cur = c,
                    None => {
                        if self.len() >= max_nodes {
                            break;
                        }
                        cur = self.push_child(cur, tok, score);
                    }
                }
            }
        }
    }

    /// Merge candidate paths into a fresh prefix tree (allocating
    /// convenience over `rebuild`; sorts by score internally).
    pub fn from_paths(base_token: i32, paths: &[CandidatePath],
                      max_nodes: usize) -> TokenTree {
        let mut order: Vec<usize> = (0..paths.len()).collect();
        order.sort_unstable_by(|&a, &b| {
            paths[b].score.partial_cmp(&paths[a].score)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut tree = TokenTree::with_capacity(max_nodes);
        tree.rebuild(
            base_token,
            order.iter().map(|&i| (paths[i].tokens.as_slice(), paths[i].score)),
            max_nodes,
        );
        tree
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn token(&self, i: usize) -> i32 {
        self.tokens[i]
    }

    pub fn parent(&self, i: usize) -> Option<usize> {
        let p = self.parent[i];
        if p < 0 { None } else { Some(p as usize) }
    }

    pub fn depth(&self, i: usize) -> usize {
        self.depth[i] as usize
    }

    pub fn score(&self, i: usize) -> f32 {
        self.score[i]
    }

    /// Whether node `j` is on node `i`'s ancestor chain (including itself).
    pub fn sees(&self, i: usize, j: usize) -> bool {
        j < MAX_TREE_NODES && (self.anc_mask[i] >> j) & 1 == 1
    }

    /// Ancestor chain of node `i`, root-first, including `i` itself.
    pub fn ancestry(&self, mut i: usize) -> Vec<usize> {
        let mut chain = vec![i];
        while let Some(p) = self.parent(i) {
            chain.push(p);
            i = p;
        }
        chain.reverse();
        chain
    }

    pub fn children(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        let mut c = self.first_child[i];
        std::iter::from_fn(move || {
            if c < 0 {
                None
            } else {
                let cur = c as usize;
                c = self.next_sibling[cur];
                Some(cur)
            }
        })
    }

    /// Write token ids into `out` (length = slot count), padding with
    /// `pad_token`.
    pub fn write_tokens(&self, out: &mut [i32], pad_token: i32) {
        out.fill(pad_token);
        let n = self.len().min(out.len());
        out[..n].copy_from_slice(&self.tokens[..n]);
    }

    /// Token ids padded to `n_slots` (allocating convenience).
    pub fn tokens_padded(&self, n_slots: usize, pad_token: i32) -> Vec<i32> {
        let mut out = vec![pad_token; n_slots];
        self.write_tokens(&mut out, pad_token);
        out
    }

    /// Write absolute positions (base_pos + depth) into `out`; padded slots
    /// get `base_pos`.
    pub fn write_positions(&self, out: &mut [i32], base_pos: usize) {
        out.fill(base_pos as i32);
        for i in 0..self.len().min(out.len()) {
            out[i] = (base_pos + self.depth[i] as usize) as i32;
        }
    }

    /// Absolute positions padded to `n_slots` (allocating convenience).
    pub fn positions_padded(&self, base_pos: usize, n_slots: usize) -> Vec<i32> {
        let mut out = vec![base_pos as i32; n_slots];
        self.write_positions(&mut out, base_pos);
        out
    }

    /// Write the additive attention bias `[n_slots, lmax + n_slots]` for one
    /// sequence into `out`: node `i` sees cache positions `< cache_len` and
    /// its ancestor chain (incl. itself) in the tree block — straight off
    /// the incremental ancestor bitset. Padded slots see only themselves
    /// (keeps softmax well-defined; their outputs are ignored).
    pub fn write_bias(&self, out: &mut [f32], cache_len: usize, lmax: usize,
                      n_slots: usize) {
        let m = lmax + n_slots;
        debug_assert_eq!(out.len(), n_slots * m);
        for i in 0..n_slots {
            let row = &mut out[i * m..(i + 1) * m];
            if i < self.len() {
                row[..cache_len].fill(0.0);
                row[cache_len..lmax].fill(NEG_INF);
                let mask = self.anc_mask[i];
                for (j, b) in row[lmax..].iter_mut().enumerate() {
                    // j >= MAX_TREE_NODES cannot hold a node (and would
                    // overflow the u128 shift)
                    *b = if j < MAX_TREE_NODES && (mask >> j) & 1 == 1 {
                        0.0
                    } else {
                        NEG_INF
                    };
                }
            } else {
                row.fill(NEG_INF);
                row[lmax + i] = 0.0; // padded slot: self-attention only
            }
        }
    }

    /// Additive attention bias (allocating convenience over `write_bias`).
    pub fn attention_bias(&self, cache_len: usize, lmax: usize,
                          n_slots: usize) -> Vec<f32> {
        let mut bias = vec![NEG_INF; n_slots * (lmax + n_slots)];
        self.write_bias(&mut bias, cache_len, lmax, n_slots);
        bias
    }

    /// Greedy token-tree verification into a caller-owned buffer: walk from
    /// the root following the base model's argmax at each accepted node.
    /// Fills `out` with the accepted node indices in order (always starts
    /// with the root) and returns the next base token (the argmax at the
    /// last accepted node).
    ///
    /// `argmax_at(node_idx) -> token` abstracts the logits row lookup.
    pub fn greedy_accept_into(&self, out: &mut Vec<usize>,
                              mut argmax_at: impl FnMut(usize) -> i32) -> i32 {
        out.clear();
        out.push(0);
        let mut cur = 0usize;
        loop {
            let want = argmax_at(cur);
            match self.find_child(cur, want) {
                Some(c) => {
                    out.push(c);
                    cur = c;
                }
                None => return want,
            }
        }
    }

    /// Allocating convenience over `greedy_accept_into`.
    pub fn greedy_accept(&self, argmax_at: impl FnMut(usize) -> i32)
                         -> (Vec<usize>, i32) {
        let mut accepted = Vec::with_capacity(self.len());
        let next = self.greedy_accept_into(&mut accepted, argmax_at);
        (accepted, next)
    }

    /// Total nodes at each depth (diagnostics / tests).
    pub fn depth_histogram(&self) -> Vec<usize> {
        let max_d = self.depth.iter().copied().max().unwrap_or(0) as usize;
        let mut h = vec![0; max_d + 1];
        for &d in &self.depth {
            h[d as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path(tokens: &[i32], score: f32) -> CandidatePath {
        CandidatePath { tokens: tokens.to_vec(), score }
    }

    #[test]
    fn prefix_merge() {
        let t = TokenTree::from_paths(
            9,
            &[path(&[1, 2, 3], -0.1), path(&[1, 2, 4], -0.2), path(&[5], -0.3)],
            32,
        );
        // root + shared [1,2] + leaves 3,4 + 5 = 6 nodes
        assert_eq!(t.len(), 6);
        assert_eq!(t.token(0), 9);
        let ones = (0..t.len()).filter(|&i| t.token(i) == 1).count();
        assert_eq!(ones, 1, "shared prefix must not duplicate");
    }

    #[test]
    fn cap_keeps_best_paths() {
        let t = TokenTree::from_paths(
            0,
            &[path(&[1, 2, 3, 4], -5.0), path(&[7], -0.1)],
            3, // root + 2
        );
        assert_eq!(t.len(), 3);
        // best path [7] must be present; worst path truncated
        assert!((0..t.len()).any(|i| t.token(i) == 7));
    }

    #[test]
    fn ancestry_and_positions() {
        let t = TokenTree::from_paths(0, &[path(&[1, 2], -0.1)], 32);
        assert_eq!(t.ancestry(2), vec![0, 1, 2]);
        let pos = t.positions_padded(10, 4);
        assert_eq!(&pos[..3], &[10, 11, 12]);
        assert_eq!(pos[3], 10); // padding
    }

    #[test]
    fn bias_structure() {
        let t = TokenTree::from_paths(0, &[path(&[1], -0.1), path(&[2], -0.2)], 32);
        let lmax = 8;
        let n = 4;
        let bias = t.attention_bias(3, lmax, n);
        let row = |i: usize| &bias[i * (lmax + n)..(i + 1) * (lmax + n)];
        // root sees cache 0..3 and itself
        assert_eq!(row(0)[..3], [0.0, 0.0, 0.0]);
        assert_eq!(row(0)[3], NEG_INF);
        assert_eq!(row(0)[lmax], 0.0);
        // node 1 sees cache, root, itself — but NOT its sibling node 2
        assert_eq!(row(1)[lmax], 0.0);
        assert_eq!(row(1)[lmax + 1], 0.0);
        assert_eq!(row(1)[lmax + 2], NEG_INF);
        // padded slot 3: self only
        assert_eq!(row(3)[lmax + 3], 0.0);
        assert!(row(3)[..lmax].iter().all(|&x| x == NEG_INF));
    }

    #[test]
    fn write_bias_matches_mask_and_reuses_buffer() {
        let t = TokenTree::from_paths(
            1, &[path(&[3, 4], -0.1), path(&[3, 5], -0.2), path(&[6], -0.3)], 16);
        let (lmax, n) = (12, 8);
        let mut buf = vec![0.42f32; n * (lmax + n)];
        t.write_bias(&mut buf, 5, lmax, n);
        for i in 0..t.len() {
            for j in 0..t.len() {
                let visible = buf[i * (lmax + n) + lmax + j] == 0.0;
                assert_eq!(visible, t.ancestry(i).contains(&j),
                           "node {i} -> {j}");
                assert_eq!(visible, t.sees(i, j));
            }
        }
        // a second write over the dirty buffer must give identical rows
        let fresh = t.attention_bias(5, lmax, n);
        assert_eq!(buf, fresh);
    }

    #[test]
    fn rebuild_reuses_arena() {
        let mut t = TokenTree::with_capacity(16);
        t.rebuild(7, [(&[1i32, 2][..], -0.1)], 16);
        assert_eq!(t.len(), 3);
        assert_eq!(t.depth(2), 2);
        t.rebuild(9, [(&[4i32][..], -0.1), (&[4i32, 5][..], -0.2)], 16);
        assert_eq!(t.len(), 3);
        assert_eq!(t.token(0), 9);
        assert_eq!(t.token(1), 4);
        assert_eq!(t.parent(2), Some(1));
        assert_eq!(t.ancestry(2), vec![0, 1, 2]);
    }

    #[test]
    fn greedy_accept_follows_argmax() {
        // tree: root(9) -> 1 -> 2 ; root -> 5
        let t = TokenTree::from_paths(9, &[path(&[1, 2], -0.1), path(&[5], -0.2)], 32);
        // argmax: at root choose 1, at node "1" choose 2, at node "2" choose 77
        let (acc, next) = t.greedy_accept(|i| match t.token(i) {
            9 => 1,
            1 => 2,
            2 => 77,
            _ => 0,
        });
        let toks: Vec<i32> = acc.iter().map(|&i| t.token(i)).collect();
        assert_eq!(toks, vec![9, 1, 2]);
        assert_eq!(next, 77);
    }

    #[test]
    fn greedy_accept_stops_on_mismatch() {
        let t = TokenTree::from_paths(9, &[path(&[1], -0.1)], 32);
        let (acc, next) = t.greedy_accept(|_| 42); // 42 not in the tree
        assert_eq!(acc, vec![0]);
        assert_eq!(next, 42);
    }

    #[test]
    fn root_only_vanilla() {
        let t = TokenTree::root_only(7);
        assert_eq!(t.len(), 1);
        let (acc, next) = t.greedy_accept(|_| 3);
        assert_eq!(acc, vec![0]);
        assert_eq!(next, 3);
    }

    #[test]
    fn tokens_padded_and_histogram() {
        let t = TokenTree::from_paths(9, &[path(&[1, 2], -0.1)], 32);
        assert_eq!(t.tokens_padded(5, 0), vec![9, 1, 2, 0, 0]);
        assert_eq!(t.depth_histogram(), vec![1, 1, 1]);
    }

    #[test]
    fn duplicate_paths_merge_fully() {
        let t = TokenTree::from_paths(
            0, &[path(&[1, 2], -0.1), path(&[1, 2], -0.3)], 32);
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn children_walks_sibling_list() {
        let t = TokenTree::from_paths(
            0, &[path(&[1], -0.1), path(&[2], -0.2), path(&[3], -0.3)], 32);
        let mut kids: Vec<i32> =
            t.children(0).map(|c| t.token(c)).collect();
        kids.sort_unstable();
        assert_eq!(kids, vec![1, 2, 3]);
        assert_eq!(t.children(1).count(), 0);
    }
}
