//! Token-tree construction and tree-attention mask building.
//!
//! Candidate paths from a drafter are merged prefix-wise into a single tree
//! (node 0 = the base token, which greedy verification has already decided).
//! The tree is what the step graph verifies in one pass: node `i` may attend
//! to the KV cache plus its own ancestor chain — exactly the additive bias
//! this module builds. The paper's CTC Transform patches candidate content
//! *before* this tree is built (see `ctc::transform_paths`), so removed
//! blank/duplicate positions never appear in the attention map.

use crate::drafters::CandidatePath;

pub const NEG_INF: f32 = -1e9;

#[derive(Debug, Clone, PartialEq)]
pub struct TreeNode {
    pub token: i32,
    /// parent node index; node 0 (root) has none
    pub parent: Option<usize>,
    pub depth: usize,
    /// cumulative candidate score down to this node (root = 0)
    pub score: f32,
}

#[derive(Debug, Clone)]
pub struct TokenTree {
    pub nodes: Vec<TreeNode>,
}

impl TokenTree {
    /// Only the base token — the degenerate tree used by vanilla decoding.
    pub fn root_only(base_token: i32) -> TokenTree {
        TokenTree {
            nodes: vec![TreeNode { token: base_token, parent: None, depth: 0, score: 0.0 }],
        }
    }

    /// Merge candidate paths (each a continuation *after* the base token)
    /// into a prefix tree capped at `max_nodes` nodes. Paths are consumed in
    /// descending score order so the cap keeps the most valuable branches —
    /// "a group of the most valuable combinations are reserved" (paper §3.3).
    pub fn from_paths(base_token: i32, paths: &[CandidatePath],
                      max_nodes: usize) -> TokenTree {
        let mut tree = TokenTree::root_only(base_token);
        let mut order: Vec<usize> = (0..paths.len()).collect();
        order.sort_by(|&a, &b| {
            paths[b].score.partial_cmp(&paths[a].score)
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        for pi in order {
            let path = &paths[pi];
            let mut cur = 0usize;
            for (d, &tok) in path.tokens.iter().enumerate() {
                // find existing child with this token
                let child = tree
                    .nodes
                    .iter()
                    .position(|n| n.parent == Some(cur) && n.token == tok);
                match child {
                    Some(c) => cur = c,
                    None => {
                        if tree.nodes.len() >= max_nodes {
                            break;
                        }
                        tree.nodes.push(TreeNode {
                            token: tok,
                            parent: Some(cur),
                            depth: d + 1,
                            score: path.score,
                        });
                        cur = tree.nodes.len() - 1;
                    }
                }
            }
        }
        tree
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Ancestor chain of node `i`, root-first, including `i` itself.
    pub fn ancestry(&self, mut i: usize) -> Vec<usize> {
        let mut chain = vec![i];
        while let Some(p) = self.nodes[i].parent {
            chain.push(p);
            i = p;
        }
        chain.reverse();
        chain
    }

    pub fn children(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.nodes
            .iter()
            .enumerate()
            .filter(move |(_, n)| n.parent == Some(i))
            .map(|(j, _)| j)
    }

    /// Token ids padded to `n_slots` (pad with `pad_token`).
    pub fn tokens_padded(&self, n_slots: usize, pad_token: i32) -> Vec<i32> {
        let mut out = vec![pad_token; n_slots];
        for (i, n) in self.nodes.iter().enumerate().take(n_slots) {
            out[i] = n.token;
        }
        out
    }

    /// Absolute positions (base_pos + depth) padded to `n_slots`.
    pub fn positions_padded(&self, base_pos: usize, n_slots: usize) -> Vec<i32> {
        let mut out = vec![base_pos as i32; n_slots];
        for (i, n) in self.nodes.iter().enumerate().take(n_slots) {
            out[i] = (base_pos + n.depth) as i32;
        }
        out
    }

    /// Additive attention bias `[n_slots, lmax + n_slots]` for one sequence:
    /// node `i` sees cache positions `< cache_len` and its ancestor chain
    /// (incl. itself) in the tree block. Padded slots see only themselves
    /// (keeps softmax well-defined; their outputs are ignored).
    pub fn attention_bias(&self, cache_len: usize, lmax: usize,
                          n_slots: usize) -> Vec<f32> {
        let m = lmax + n_slots;
        let mut bias = vec![NEG_INF; n_slots * m];
        for i in 0..n_slots {
            let row = &mut bias[i * m..(i + 1) * m];
            if i < self.nodes.len() {
                row[..cache_len].fill(0.0);
                for a in self.ancestry(i) {
                    row[lmax + a] = 0.0;
                }
            } else {
                row[lmax + i] = 0.0; // padded slot: self-attention only
            }
        }
        bias
    }

    /// Greedy token-tree verification: walk from the root following the base
    /// model's argmax at each accepted node. Returns the accepted node
    /// indices in order (always starts with the root) and the next base
    /// token (the argmax at the last accepted node).
    ///
    /// `argmax_at(node_idx) -> token` abstracts the logits row lookup.
    pub fn greedy_accept(&self, mut argmax_at: impl FnMut(usize) -> i32)
                         -> (Vec<usize>, i32) {
        let mut accepted = vec![0usize];
        let mut cur = 0usize;
        loop {
            let want = argmax_at(cur);
            let next = self
                .children(cur)
                .find(|&c| self.nodes[c].token == want);
            match next {
                Some(c) => {
                    accepted.push(c);
                    cur = c;
                }
                None => return (accepted, want),
            }
        }
    }

    /// Total nodes at each depth (diagnostics / tests).
    pub fn depth_histogram(&self) -> Vec<usize> {
        let max_d = self.nodes.iter().map(|n| n.depth).max().unwrap_or(0);
        let mut h = vec![0; max_d + 1];
        for n in &self.nodes {
            h[n.depth] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path(tokens: &[i32], score: f32) -> CandidatePath {
        CandidatePath { tokens: tokens.to_vec(), score }
    }

    #[test]
    fn prefix_merge() {
        let t = TokenTree::from_paths(
            9,
            &[path(&[1, 2, 3], -0.1), path(&[1, 2, 4], -0.2), path(&[5], -0.3)],
            32,
        );
        // root + shared [1,2] + leaves 3,4 + 5 = 6 nodes
        assert_eq!(t.len(), 6);
        assert_eq!(t.nodes[0].token, 9);
        let ones: Vec<_> = t.nodes.iter().filter(|n| n.token == 1).collect();
        assert_eq!(ones.len(), 1, "shared prefix must not duplicate");
    }

    #[test]
    fn cap_keeps_best_paths() {
        let t = TokenTree::from_paths(
            0,
            &[path(&[1, 2, 3, 4], -5.0), path(&[7], -0.1)],
            3, // root + 2
        );
        assert_eq!(t.len(), 3);
        // best path [7] must be present; worst path truncated
        assert!(t.nodes.iter().any(|n| n.token == 7));
    }

    #[test]
    fn ancestry_and_positions() {
        let t = TokenTree::from_paths(0, &[path(&[1, 2], -0.1)], 32);
        assert_eq!(t.ancestry(2), vec![0, 1, 2]);
        let pos = t.positions_padded(10, 4);
        assert_eq!(&pos[..3], &[10, 11, 12]);
        assert_eq!(pos[3], 10); // padding
    }

    #[test]
    fn bias_structure() {
        let t = TokenTree::from_paths(0, &[path(&[1], -0.1), path(&[2], -0.2)], 32);
        let lmax = 8;
        let n = 4;
        let bias = t.attention_bias(3, lmax, n);
        let row = |i: usize| &bias[i * (lmax + n)..(i + 1) * (lmax + n)];
        // root sees cache 0..3 and itself
        assert_eq!(row(0)[..3], [0.0, 0.0, 0.0]);
        assert_eq!(row(0)[3], NEG_INF);
        assert_eq!(row(0)[lmax], 0.0);
        // node 1 sees cache, root, itself — but NOT its sibling node 2
        assert_eq!(row(1)[lmax], 0.0);
        assert_eq!(row(1)[lmax + 1], 0.0);
        assert_eq!(row(1)[lmax + 2], NEG_INF);
        // padded slot 3: self only
        assert_eq!(row(3)[lmax + 3], 0.0);
        assert!(row(3)[..lmax].iter().all(|&x| x == NEG_INF));
    }

    #[test]
    fn greedy_accept_follows_argmax() {
        // tree: root(9) -> 1 -> 2 ; root -> 5
        let t = TokenTree::from_paths(9, &[path(&[1, 2], -0.1), path(&[5], -0.2)], 32);
        // argmax: at root choose 1, at node "1" choose 2, at node "2" choose 77
        let (acc, next) = t.greedy_accept(|i| match t.nodes[i].token {
            9 => 1,
            1 => 2,
            2 => 77,
            _ => 0,
        });
        let toks: Vec<i32> = acc.iter().map(|&i| t.nodes[i].token).collect();
        assert_eq!(toks, vec![9, 1, 2]);
        assert_eq!(next, 77);
    }

    #[test]
    fn greedy_accept_stops_on_mismatch() {
        let t = TokenTree::from_paths(9, &[path(&[1], -0.1)], 32);
        let (acc, next) = t.greedy_accept(|_| 42); // 42 not in the tree
        assert_eq!(acc, vec![0]);
        assert_eq!(next, 42);
    }

    #[test]
    fn root_only_vanilla() {
        let t = TokenTree::root_only(7);
        assert_eq!(t.len(), 1);
        let (acc, next) = t.greedy_accept(|_| 3);
        assert_eq!(acc, vec![0]);
        assert_eq!(next, 3);
    }

    #[test]
    fn tokens_padded_and_histogram() {
        let t = TokenTree::from_paths(9, &[path(&[1, 2], -0.1)], 32);
        assert_eq!(t.tokens_padded(5, 0), vec![9, 1, 2, 0, 0]);
        assert_eq!(t.depth_histogram(), vec![1, 1, 1]);
    }

    #[test]
    fn duplicate_paths_merge_fully() {
        let t = TokenTree::from_paths(
            0, &[path(&[1, 2], -0.1), path(&[1, 2], -0.3)], 32);
        assert_eq!(t.len(), 3);
    }
}
