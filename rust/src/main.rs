//! `ctcdraft` CLI — leader entrypoint for the CTC-drafter serving stack.
//!
//! Subcommands:
//!   info      — inspect artifacts/manifest
//!   generate  — one-shot generation with any speculation method
//!   eval      — quick β/γ evaluation on a workload slice
//!   serve     — start the TCP JSON-lines server (router + workers)
//!   client    — query a running server
//!   warmup    — precompile every graph of a model

use anyhow::{bail, Result};

use ctcdraft::adapt::{BetaPolicy, SpecMode};
use ctcdraft::bench;
use ctcdraft::config::{EngineConfig, FrontendConfig, Method, MockServeConfig,
                       SupervisorConfig};
use ctcdraft::drafters::{parse_portfolio, DrafterKind};
use ctcdraft::engine::Engine;
use ctcdraft::metrics::RunSummary;
use ctcdraft::runtime::Runtime;
use ctcdraft::sched::{Priority, SloPolicy};
use ctcdraft::server::{Client, Server, ServerConfig};
use ctcdraft::supervisor::LadderConfig;
use ctcdraft::testkit::{MockCluster, MockSched, SchedulerSim, SimOptions};
use ctcdraft::util::cli::Cli;
use ctcdraft::workload::{FaultPlan, Trace};
use ctcdraft::{default_artifacts_dir, workload};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", usage());
        std::process::exit(2);
    };
    let result = match cmd.as_str() {
        "info" => cmd_info(rest),
        "generate" => cmd_generate(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "warmup" => cmd_warmup(rest),
        "sim" => cmd_sim(rest),
        "scenbench" => cmd_scenbench(rest),
        "specbench" => cmd_specbench(rest),
        "connbench" => cmd_connbench(rest),
        "shedreplay" => cmd_shedreplay(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    "ctcdraft — CTC-drafter speculative decoding server\n\n\
     commands:\n\
     \x20 info                       show artifact manifest summary\n\
     \x20 generate --prompt <text>   one-shot generation\n\
     \x20 eval                       quick workload evaluation (β, tok/s)\n\
     \x20 serve                      start the TCP server\n\
     \x20 client --prompt <text>     query a running server\n\
     \x20 warmup                     precompile all graphs for a model\n\
     \x20 sim                        artifact-free scheduler-sim replay\n\
     \x20                            (prints the canonical event log; \
     --scenario runs the library)\n\
     \x20 scenbench                  run every library scenario through the\n\
     \x20                            sim (BENCH_scenarios.json)\n\
     \x20 specbench                  portfolio-vs-single-drafter sim bench\n\
     \x20                            on spec_mixed (BENCH_portfolio.json)\n\
     \x20 connbench                  connection fan-in overhead bench\n\
     \x20                            (mock serving mode; BENCH_conn_fanin)\n\
     \x20 shedreplay                 deterministic write-queue shed replay\n\
     \x20                            (prints the canonical shed log)\n\n\
     run `ctcdraft <command> --help` for options"
        .to_string()
}

fn engine_opts(cli: Cli) -> Cli {
    cli.opt("artifacts", "artifacts directory", None)
        .opt("model", "model name", Some("vic-tiny"))
        .opt("method", "vanilla|medusa|hydra|ctc", Some("ctc"))
        .opt("max-new", "max new tokens", Some("64"))
        .opt("temperature", "sampling temperature (0 = greedy)", Some("0"))
        .opt("seed", "rng seed", Some("0"))
        .opt("queue-cap", "admit-queue bound (0 = unbounded); full => busy",
             Some("0"))
        .opt("kv-pool",
             "KV pool positions — cluster-wide under serve, shared by all \
              workers (0 = lmax × slots, × workers when serving)", Some("0"))
        .opt("prefill-chunk",
             "per-round prefill token budget (0 = unlimited): long prompts \
              prefill in chunks interleaved with decode rounds", Some("0"))
        .opt("interactive-deadline",
             "default interactive deadline (scheduler steps)", Some("256"))
        .opt("batch-deadline",
             "default batch deadline (scheduler steps)", Some("2048"))
        .opt("batch-aging",
             "queue age (steps) after which batch competes as interactive \
              (0 = no aging)", Some("512"))
        .opt("beta-policy",
             "tree-width policy: fixed (paper static budget) | adaptive \
              (β-aware: width/depth from batch size + acceptance EWMA)",
             Some("fixed"))
        .opt("drafter-portfolio",
             "comma list of drafters every worker instantiates \
              (ctc|lookup|vanilla|medusa|hydra|none); the first is the \
              primary. Empty = just --method (byte-compatible default)",
             None)
        .opt("spec-policy",
             "per-slot drafter selection: fixed (every slot runs the \
              primary) | auto (online per-sequence selection from \
              acceptance EWMAs with hysteresis) | off (plain decode)",
             Some("fixed"))
        .flag("no-ctc-transform", "disable the CTC transform (ablation)")
}

fn build_slo(a: &ctcdraft::util::cli::Args) -> SloPolicy {
    SloPolicy {
        interactive_deadline: a.u64("interactive-deadline", 256),
        batch_deadline: a.u64("batch-deadline", 2048),
        batch_aging_steps: a.u64("batch-aging", 512),
        prefill_chunk: a.usize("prefill-chunk", 0),
    }
}

fn build_engine_cfg(a: &ctcdraft::util::cli::Args) -> Result<EngineConfig> {
    Ok(EngineConfig {
        model: a.get_or("model", "vic-tiny").to_string(),
        method: Method::parse(a.get_or("method", "ctc"))?,
        ctc_transform: !a.flag("no-ctc-transform"),
        max_new_tokens: a.usize("max-new", 64),
        temperature: a.f64("temperature", 0.0) as f32,
        seed: a.u64("seed", 0),
        queue_cap: a.usize("queue-cap", 0),
        kv_pool_positions: a.usize("kv-pool", 0),
        slo: build_slo(a),
        beta_policy: BetaPolicy::parse(a.get_or("beta-policy", "fixed"))?,
        drafter_portfolio: match a.get("drafter-portfolio") {
            Some(s) => parse_portfolio(s)?,
            None => Vec::new(),
        },
        spec_mode: SpecMode::parse(a.get_or("spec-policy", "fixed"))?,
        ..EngineConfig::default()
    })
}

fn artifacts_dir(a: &ctcdraft::util::cli::Args) -> std::path::PathBuf {
    a.get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir)
}

fn parse_args(cli: Cli, argv: &[String]) -> Result<ctcdraft::util::cli::Args> {
    match cli.parse_from(argv.iter().cloned()) {
        Ok(a) => Ok(a),
        Err(usage) => {
            println!("{usage}");
            std::process::exit(2);
        }
    }
}

// ---------------------------------------------------------------- info
fn cmd_info(argv: &[String]) -> Result<()> {
    let cli = Cli::new("ctcdraft info", "artifact summary")
        .opt("artifacts", "artifacts directory", None);
    let a = parse_args(cli, argv)?;
    let rt = Runtime::load(artifacts_dir(&a))?;
    let m = &rt.manifest;
    println!("artifacts: {}", m.dir.display());
    println!("vocab: {} (+blank {})", m.constants.vocab_size, m.constants.blank_id);
    println!("lmax {}  tree_n {}  slots {}  window {}",
             m.constants.lmax, m.constants.tree_n,
             m.constants.draft_slots, m.constants.hidden_win);
    for (name, meta) in &m.models {
        let c = &meta.config;
        println!(
            "model {name:10} analog={:18} L={} D={} H={} act={} graphs={} heads={:?}",
            c.analog, c.layers, c.d_model, c.n_heads, c.act,
            meta.graphs.len(),
            meta.heads.keys().collect::<Vec<_>>()
        );
    }
    for (name, _) in &m.kernels {
        println!("kernel {name}");
    }
    Ok(())
}

// ---------------------------------------------------------------- generate
fn cmd_generate(argv: &[String]) -> Result<()> {
    let cli = engine_opts(Cli::new("ctcdraft generate", "one-shot generation"))
        .opt("prompt", "raw question (chat template is applied)", None)
        .flag("raw", "do not apply the chat template");
    let a = parse_args(cli, argv)?;
    let Some(prompt) = a.get("prompt") else { bail!("--prompt required") };
    let cfg = build_engine_cfg(&a)?;
    let max_new = cfg.max_new_tokens;
    let rt = Runtime::load(artifacts_dir(&a))?;
    let mut engine = Engine::new(rt, cfg)?;
    let full_prompt = if a.flag("raw") {
        prompt.to_string()
    } else {
        engine.format_prompt(prompt)
    };
    let out = engine.generate(&full_prompt, max_new)?;
    println!("{}", out.text);
    let s = &out.stats;
    let (bm, dr, tr, ot) = s.breakdown.percentages();
    eprintln!(
        "\n[{} tokens, {} steps, β={:.2}, {:.2}s | base {bm:.1}% draft {dr:.1}% \
         transform {tr:.1}% other {ot:.1}%]",
        s.new_tokens, s.steps, s.accepted_per_step(), s.wall_secs
    );
    Ok(())
}

// ---------------------------------------------------------------- eval
fn cmd_eval(argv: &[String]) -> Result<()> {
    let cli = engine_opts(Cli::new("ctcdraft eval", "quick workload evaluation"))
        .opt("workload", "mtbench|gsm8k", Some("mtbench"))
        .opt("n", "questions (mtbench: per category)", Some("1"));
    let a = parse_args(cli, argv)?;
    let cfg = build_engine_cfg(&a)?;
    let n = a.usize("n", 1);
    let qs = match a.get_or("workload", "mtbench") {
        "mtbench" => workload::mtbench(n, cfg.seed),
        "gsm8k" => workload::gsm8k(n * 8, cfg.seed),
        other => bail!("unknown workload {other}"),
    };
    let rt = Runtime::load(artifacts_dir(&a))?;
    let max_new = cfg.max_new_tokens;
    let mut engine = Engine::new(rt, cfg)?;
    let prompts: Vec<(String, usize)> = qs
        .iter()
        .map(|q| (engine.format_prompt(&q.text), max_new))
        .collect();
    let t0 = std::time::Instant::now();
    let outs = engine.generate_batch(&prompts)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut sum = RunSummary::default();
    for o in &outs {
        sum.merge(&o.stats.summary());
    }
    println!(
        "{} questions | {} tokens | β={:.2} | {:.1} tok/s | wall {wall:.1}s",
        outs.len(), sum.total_tokens, sum.beta(),
        sum.total_tokens as f64 / wall
    );
    Ok(())
}

// ---------------------------------------------------------------- serve
fn cmd_serve(argv: &[String]) -> Result<()> {
    let cli = engine_opts(Cli::new("ctcdraft serve", "start the TCP server"))
        .opt("addr", "listen address", Some("127.0.0.1:7700"))
        .opt("workers", "engine worker threads", Some("1"))
        .opt("io-threads",
             "connection driver threads (0 = one per core); each multiplexes \
              many non-blocking connections", Some("0"))
        .opt("conn-write-cap",
             "bounded per-connection write queue (frames); a client that \
              stops reading past this is shed (connection closed, request \
              cancelled)", Some("256"))
        .opt("max-conns",
             "open-connection ceiling; accepts past it get a terminal busy \
              frame instead of a thread or a driver slot", Some("4096"))
        .opt("drain-deadline-ms",
             "graceful-stop bound on flushing connection write queues",
             Some("5000"))
        .flag("mock",
              "serve the deterministic mock engine (no artifacts needed; \
               token streams are a pure function of the prompt — the \
               concurrency-test serving mode)")
        .opt("mock-slots", "mock mode: batch slots per worker", Some("64"))
        .opt("mock-step-delay-us", "mock mode: round pacing (µs)",
             Some("500"))
        .opt("mock-fault-seed",
             "mock mode: seeded worker fault injection (panics + stalls) to \
              exercise supervision, failover and the `retrying` wire frame",
             None)
        .opt("watchdog-ms",
             "round watchdog: wall-clock ms a worker heartbeat may stagnate \
              before placement routes around it (0 = off)", Some("0"))
        .opt("retry-budget",
             "worker-loss failovers per request before a terminal busy",
             Some("2"));
    let a = parse_args(cli, argv)?;
    let frontend = FrontendConfig {
        io_threads: a.usize("io-threads", 0),
        conn_write_cap: a.usize("conn-write-cap", 256),
        max_conns: a.usize("max-conns", 4096),
        drain_deadline_ms: a.u64("drain-deadline-ms", 5000),
    };
    let mock = a.flag("mock").then(|| MockServeConfig {
        slots: a.usize("mock-slots", 64),
        queue_cap: a.usize("queue-cap", 0),
        step_delay_us: a.u64("mock-step-delay-us", 500),
        fault_seed: a.get("mock-fault-seed").and_then(|v| v.parse().ok()),
        ..MockServeConfig::default()
    });
    let cfg = ServerConfig {
        addr: a.get_or("addr", "127.0.0.1:7700").to_string(),
        workers: a.usize("workers", 1),
        artifacts: artifacts_dir(&a),
        engine: build_engine_cfg(&a)?,
        frontend,
        mock,
        supervisor: SupervisorConfig {
            watchdog_ms: a.u64("watchdog-ms", 0),
            retry_budget: a.usize("retry-budget", 2) as u32,
            ..SupervisorConfig::default()
        },
    };
    let server = Server::start(cfg)?;
    println!("listening on {} — ctrl-c to stop", server.local_addr);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

// ---------------------------------------------------------------- client
fn cmd_client(argv: &[String]) -> Result<()> {
    let cli = Cli::new("ctcdraft client", "query a running server")
        .opt("addr", "server address", Some("127.0.0.1:7700"))
        .opt("prompt", "question text", None)
        .opt("max-new", "max new tokens", Some("64"))
        .opt("id", "client-chosen request id", Some("1"))
        .opt("cancel", "cancel the request with this id and exit", None)
        .opt("class", "priority class: interactive|batch", Some("interactive"))
        .opt("deadline", "relative deadline in scheduler steps", None)
        .flag("stream", "print tokens as they are accepted")
        .flag("stats", "print server scheduler stats and exit");
    let a = parse_args(cli, argv)?;
    let mut client = Client::connect(a.get_or("addr", "127.0.0.1:7700"))?;
    if a.flag("stats") {
        println!("{}", client.stats_detail()?.to_string());
        return Ok(());
    }
    if let Some(id) = a.get("cancel") {
        let id: i64 = id.parse()?;
        let ok = client.cancel(id)?;
        println!("cancel id={id}: {}", if ok { "cancelled" } else { "not found" });
        return Ok(());
    }
    let Some(prompt) = a.get("prompt") else { bail!("--prompt required") };
    let id = a.get("id").and_then(|v| v.parse().ok()).unwrap_or(1);
    let max_new = a.usize("max-new", 64);
    let class = Priority::parse(a.get_or("class", "interactive"))?;
    let deadline = a.get("deadline").and_then(|v| v.parse::<u64>().ok());
    let stream = a.flag("stream");
    use std::io::Write as _;
    let outcome = client.generate_stream_opts(
        id, prompt, max_new, stream, class, deadline, |t| {
            print!("{t}");
            let _ = std::io::stdout().flush();
        })?;
    match outcome {
        ctcdraft::server::GenerateOutcome::Done(r) => {
            if stream {
                println!();
            } else {
                println!("{}", r.text);
            }
            eprintln!("[{} tokens, {} steps, β={:.2}, {:.0}ms]",
                      r.tokens, r.steps, r.beta, r.ms);
        }
        ctcdraft::server::GenerateOutcome::Busy { retry_after_steps } => {
            match retry_after_steps {
                Some(n) => bail!("server busy (retry after ~{n} steps)"),
                None => bail!("server busy"),
            }
        }
        ctcdraft::server::GenerateOutcome::Cancelled => bail!("cancelled"),
    }
    Ok(())
}

// ---------------------------------------------------------------- sim
/// Artifact-free scheduler-simulation replay: drive `MockSched` (or, with
/// `--workers N`, a `MockCluster` of N workers over ONE shared KV block
/// pool behind the production placement policy) through a class-tagged
/// Poisson trace — or, with `--trace multiturn`, prefix-chained
/// conversations exercising the prefix-sharing KV cache — and print the
/// canonical event log to stdout. Two runs with the same options MUST
/// print identical logs — `check.sh` diffs a double replay (single-worker
/// AND cluster, both traces) as the determinism gate, and diffs the warm
/// multiturn run's `prefill_steps` against `--no-prefix-share` as the
/// cache-reuse gate.
fn cmd_sim(argv: &[String]) -> Result<()> {
    let cli = Cli::new("ctcdraft sim", "deterministic scheduler-sim replay")
        .opt("seed", "trace + backend seed", Some("7"))
        .opt("workers", "mock workers over one shared pool", Some("1"))
        .opt("slots", "batch slots", Some("4"))
        .opt("queue-cap", "admit-queue bound (0 = unbounded)", Some("8"))
        .opt("pool", "shared KV pool positions (cluster-wide)", Some("256"))
        .opt("trace",
             "workload shape: poisson (class-tagged MT-bench arrivals) | \
              multiturn (prefix-chained conversations for the prefix-\
              sharing cache) | spec_mixed (copy-heavy + chat + rejection-\
              heavy tenants for the drafter-portfolio policy)",
             Some("poisson"))
        .opt("scenario",
             "named scenario from the workload library (overrides --trace \
              and installs the scenario's tenant specs — token buckets, WFQ \
              weights, pool-share caps): diurnal | agentic | longctx | \
              noisy_neighbor | cancel_storm", None)
        .opt("requests", "questions per MT-bench category (poisson)",
             Some("2"))
        .opt("convs", "concurrent conversations (multiturn)", Some("6"))
        .opt("turns", "turns per conversation (multiturn)", Some("3"))
        .opt("max-new", "max new tokens per request", Some("24"))
        .opt("mean-gap", "mean arrival gap (steps; poisson)", Some("1.5"))
        .opt("batch-frac", "fraction of requests tagged batch", Some("0.5"))
        .opt("interactive-deadline", "interactive deadline (steps)", Some("32"))
        .opt("batch-deadline", "batch deadline (steps)", Some("256"))
        .opt("batch-aging", "batch aging bound (steps; 0 = off)", Some("64"))
        .opt("prefill-chunk", "per-round prefill budget (0 = unlimited)",
             Some("8"))
        .opt("beta-policy",
             "β analog for the mock: fixed | adaptive (batch-adaptive \
              accepted-token range via adapt::BetaController)", Some("fixed"))
        .opt("spec-policy",
             "per-slot drafter selection (the production adapt::SpecPolicy \
              over the mock's profile-modeled acceptance): fixed | auto | \
              off. Non-fixed installs the portfolio and logs \
              drafter-switch events", Some("fixed"))
        .opt("drafter-portfolio",
             "comma list of drafter kinds for the mock portfolio (first = \
              primary); defaults to ctc,lookup,none when --spec-policy is \
              not fixed", None)
        .opt("cancel-prob", "per-request cancellation probability", Some("0"))
        .opt("faults",
             "seeded fault plan: worker panics, step stalls, pool spikes and \
              conn errors injected at exact virtual steps (chaos gate; \
              forces the cluster backend and arms the degradation ladder)",
             None)
        .flag("no-prefix-share",
              "disable the prefix-sharing KV cache (cold baseline; \
               check.sh diffs its prefill_steps against the warm run)")
        .flag("summary", "print a run summary to stderr");
    let a = parse_args(cli, argv)?;
    let seed = a.u64("seed", 7);
    let policy = SloPolicy {
        interactive_deadline: a.u64("interactive-deadline", 32),
        batch_deadline: a.u64("batch-deadline", 256),
        batch_aging_steps: a.u64("batch-aging", 64),
        prefill_chunk: a.usize("prefill-chunk", 8),
    };
    // --scenario overrides --trace: the library scenario brings its own
    // trace, tenant specs (buckets / weights / pool shares) and cancel
    // probability; the explicit --cancel-prob flag still wins when set
    let scenario = a
        .get("scenario")
        .map(|name| {
            workload::scenario(name, seed).ok_or_else(|| {
                anyhow::anyhow!("unknown --scenario {name} ({})",
                                workload::SCENARIOS.join(" | "))
            })
        })
        .transpose()?;
    let (trace, tenants, cancel_prob) = match scenario {
        Some(sc) => {
            let user_cp = a.f64("cancel-prob", 0.0);
            let cp = if user_cp > 0.0 { user_cp } else { sc.cancel_prob };
            (sc.trace, sc.tenants, cp)
        }
        None => {
            let trace = match a.get_or("trace", "poisson") {
                "poisson" => Trace::poisson_with_classes(
                    workload::mtbench(a.usize("requests", 2), seed),
                    a.usize("max-new", 24),
                    a.f64("mean-gap", 1.5),
                    seed,
                    a.f64("batch-frac", 0.5),
                    policy.interactive_deadline,
                    policy.batch_deadline,
                ),
                "multiturn" => Trace::multiturn(
                    a.usize("convs", 6),
                    a.usize("turns", 3),
                    a.usize("max-new", 24),
                    seed,
                ),
                "spec_mixed" => workload::spec_mixed(seed),
                other => bail!("unknown --trace {other} \
                                (poisson | multiturn | spec_mixed)"),
            };
            (trace, Vec::new(), a.f64("cancel-prob", 0.0))
        }
    };
    let beta = BetaPolicy::parse(a.get_or("beta-policy", "fixed"))?;
    // drafter-portfolio policy: installed only when asked for, so default
    // replays stay byte-identical to previous releases
    let spec_mode = SpecMode::parse(a.get_or("spec-policy", "fixed"))?;
    let spec_kinds = a.get("drafter-portfolio")
        .map(|s| parse_portfolio(s))
        .transpose()?;
    let spec = if spec_kinds.is_some() || spec_mode != SpecMode::Fixed {
        Some((spec_mode, spec_kinds.unwrap_or_else(|| vec![
            DrafterKind::Ctc, DrafterKind::Lookup, DrafterKind::None,
        ])))
    } else {
        None
    };
    let share = !a.flag("no-prefix-share");
    let workers = a.usize("workers", 1);
    // A fault plan is injected through the cluster backend (it owns the
    // supervision machinery), so `--faults` forces MockCluster even for a
    // single worker. Fault-free runs keep the legacy backend choice and
    // their byte-identical event logs.
    let fault_plan = a
        .get("faults")
        .map(|v| v.parse::<u64>())
        .transpose()?
        .map(|fs| FaultPlan::seeded(fs, workers.max(1), 32));
    let faults_on = fault_plan.is_some();
    let sim = SchedulerSim::new(SimOptions {
        cancel_prob,
        seed,
        faults: fault_plan,
        ..Default::default()
    });
    let report = if workers > 1 || faults_on {
        let mut backend = MockCluster::new(
            workers.max(1),
            a.usize("slots", 4),
            a.usize("queue-cap", 8),
            a.usize("pool", 256),
            seed,
        )
        .with_policy(policy)
        .with_beta(beta)
        .with_prefix_sharing(share);
        if let Some((mode, kinds)) = &spec {
            backend = backend.with_spec(*mode, kinds);
        }
        if !tenants.is_empty() {
            backend = backend.with_tenants(&tenants);
        }
        if faults_on {
            backend = backend.with_ladder(LadderConfig::default());
        }
        sim.run(&mut backend, &trace)?
    } else {
        let mut backend = MockSched::new(
            a.usize("slots", 4),
            a.usize("queue-cap", 8),
            a.usize("pool", 256),
            seed,
        )
        .with_policy(policy)
        .with_beta(beta)
        .with_prefix_sharing(share);
        if let Some((mode, kinds)) = &spec {
            backend = backend.with_spec(*mode, kinds);
        }
        if !tenants.is_empty() {
            backend = backend.with_tenants(&tenants);
        }
        sim.run(&mut backend, &trace)?
    };
    print!("{}", report.event_log);
    if a.flag("summary") {
        eprintln!(
            "steps={} finished={} evictions={} busy={} deadline_misses={} \
             interleaved_rounds={} max_queue_depth={} prefill_steps={} \
             prefix_hits={} prefix_misses={} prefix_saved={} prefix_forks={} \
             faults_injected={} failovers={} failed_streams={}",
            report.steps, report.finished.len(), report.evictions,
            report.busy_rejections, report.deadline_misses,
            report.interleaved_rounds, report.max_queue_depth,
            report.prefill_steps, report.prefix_hits, report.prefix_misses,
            report.prefix_blocks_saved, report.prefix_forks,
            report.faults_injected, report.failovers, report.failed_streams
        );
        // per-tenant breakdown (only tagged traces populate it); the
        // noisy_neighbor check.sh gate parses these lines for the
        // co-tenant miss-rate bound
        for (name, t) in &report.tenants {
            eprintln!(
                "tenant={name} submitted={} finished={} busy={} misses={} \
                 miss_rate={:.4} ttft_mean={:.2} wait_mean={:.2} tokens={}",
                t.submitted, t.finished, t.busy, t.deadline_misses,
                t.miss_rate(), t.ttft_mean(), t.wait_mean(), t.tokens
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------- scenbench
/// Run every scenario in the workload library (`workload::SCENARIOS`)
/// through the scheduler sim and emit `BENCH_scenarios.json`: per-scenario
/// deadline-miss rate, mean TTFT, throughput, and the per-tenant
/// admission/latency breakdown. Fully seeded — same flags produce the
/// same JSON bytes, so check.sh can smoke-validate the artifact.
fn cmd_scenbench(argv: &[String]) -> Result<()> {
    use ctcdraft::util::json::Json;
    let cli = Cli::new("ctcdraft scenbench",
                       "run the scenario library through the sim")
        .opt("seed", "scenario + backend seed", Some("7"))
        .opt("workers", "mock workers over one shared pool", Some("1"))
        .opt("slots", "batch slots", Some("4"))
        .opt("queue-cap", "admit-queue bound (0 = unbounded)", Some("8"))
        .opt("pool", "shared KV pool positions (cluster-wide)", Some("256"))
        .flag("smoke", "accepted for CI symmetry (scenarios are CI-sized)");
    let a = parse_args(cli, argv)?;
    let seed = a.u64("seed", 7);
    let workers = a.usize("workers", 1);
    let policy = SloPolicy {
        interactive_deadline: 32,
        batch_deadline: 256,
        batch_aging_steps: 64,
        prefill_chunk: 8,
    };
    let mut results = Vec::new();
    for name in workload::SCENARIOS {
        let sc = workload::scenario(name, seed)
            .ok_or_else(|| anyhow::anyhow!("scenario {name} missing"))?;
        let sim = SchedulerSim::new(SimOptions {
            cancel_prob: sc.cancel_prob,
            seed,
            ..Default::default()
        });
        let report = if workers > 1 {
            let mut backend = MockCluster::new(
                workers,
                a.usize("slots", 4),
                a.usize("queue-cap", 8),
                a.usize("pool", 256),
                seed,
            )
            .with_policy(policy)
            .with_tenants(&sc.tenants);
            sim.run(&mut backend, &sc.trace)?
        } else {
            let mut backend = MockSched::new(
                a.usize("slots", 4),
                a.usize("queue-cap", 8),
                a.usize("pool", 256),
                seed,
            )
            .with_policy(policy)
            .with_tenants(&sc.tenants);
            sim.run(&mut backend, &sc.trace)?
        };
        let tokens: usize =
            report.finished.iter().map(|o| o.token_ids.len()).sum();
        let finished = report.finished.len();
        let miss_rate = if finished == 0 {
            0.0
        } else {
            report.deadline_misses as f64 / finished as f64
        };
        let (ttft_sum, ttft_n) = report.tenants.values().fold(
            (0u64, 0usize),
            |(s, n), t| (s + t.ttft_sum_steps, n + t.ttft_count),
        );
        let ttft_mean =
            if ttft_n == 0 { 0.0 } else { ttft_sum as f64 / ttft_n as f64 };
        let throughput = if report.steps == 0 {
            0.0
        } else {
            tokens as f64 / report.steps as f64
        };
        let tenants: std::collections::BTreeMap<String, Json> = report
            .tenants
            .iter()
            .map(|(tn, t)| {
                (tn.clone(), Json::obj(vec![
                    ("submitted", Json::num(t.submitted as f64)),
                    ("finished", Json::num(t.finished as f64)),
                    ("busy", Json::num(t.busy as f64)),
                    ("deadline_misses", Json::num(t.deadline_misses as f64)),
                    ("miss_rate", Json::num(t.miss_rate())),
                    ("ttft_mean_steps", Json::num(t.ttft_mean())),
                    ("wait_mean_steps", Json::num(t.wait_mean())),
                    ("tokens", Json::num(t.tokens as f64)),
                ]))
            })
            .collect();
        eprintln!(
            "scenario={name} steps={} finished={finished} busy={} \
             misses={} miss_rate={miss_rate:.4} ttft_mean={ttft_mean:.2} \
             tok_per_step={throughput:.3}",
            report.steps, report.busy_rejections, report.deadline_misses
        );
        results.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("steps", Json::num(report.steps as f64)),
            ("finished", Json::num(finished as f64)),
            ("busy", Json::num(report.busy_rejections as f64)),
            ("deadline_misses", Json::num(report.deadline_misses as f64)),
            ("miss_rate", Json::num(miss_rate)),
            ("ttft_mean_steps", Json::num(ttft_mean)),
            ("throughput_tokens_per_step", Json::num(throughput)),
            ("tenants", Json::Obj(tenants)),
        ]));
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("scenarios")),
        ("seed", Json::num(seed as f64)),
        ("workers", Json::num(workers as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_scenarios.json";
    std::fs::write(path, format!("{doc}\n"))?;
    eprintln!("wrote {path}");
    Ok(())
}

// ---------------------------------------------------------------- specbench
/// Run the `spec_mixed` workload through the scheduler sim once with the
/// drafter portfolio in `auto` and once pinned to each portfolio member as
/// a fixed single drafter, and emit `BENCH_portfolio.json`. The
/// portfolio-wins invariant — the auto policy's accepted-tokens/step
/// matches or beats every single-drafter run — is check.sh's gate on the
/// online selector. Fully seeded: same flags produce the same JSON bytes.
fn cmd_specbench(argv: &[String]) -> Result<()> {
    use ctcdraft::util::json::Json;
    let cli = Cli::new("ctcdraft specbench",
                       "portfolio vs single-drafter sim bench")
        .opt("seed", "trace + backend seed", Some("7"))
        .opt("slots", "batch slots", Some("4"))
        .opt("queue-cap", "admit-queue bound (0 = unbounded)", Some("0"))
        .opt("pool", "KV pool positions", Some("256"))
        .opt("drafter-portfolio",
             "comma list of drafter kinds (first = primary)",
             Some("ctc,lookup,none"))
        .flag("smoke", "accepted for CI symmetry (the sim is CI-sized)");
    let a = parse_args(cli, argv)?;
    let seed = a.u64("seed", 7);
    let kinds = parse_portfolio(a.get_or("drafter-portfolio",
                                         "ctc,lookup,none"))?;
    let policy = SloPolicy {
        interactive_deadline: 32,
        batch_deadline: 256,
        batch_aging_steps: 64,
        prefill_chunk: 8,
    };
    let trace = workload::spec_mixed(seed);
    let run = |name: String, mode: SpecMode, ks: &[DrafterKind]|
               -> Result<Json> {
        let sim = SchedulerSim::new(SimOptions {
            seed,
            ..Default::default()
        });
        let mut backend = MockSched::new(
            a.usize("slots", 4),
            a.usize("queue-cap", 0),
            a.usize("pool", 256),
            seed,
        )
        .with_policy(policy)
        .with_spec(mode, ks);
        let report = sim.run(&mut backend, &trace)?;
        let tokens: usize =
            report.finished.iter().map(|o| o.token_ids.len()).sum();
        let per_step = if report.steps == 0 {
            0.0
        } else {
            tokens as f64 / report.steps as f64
        };
        let switches = backend
            .spec_policy()
            .map(|p| p.switches())
            .unwrap_or(0);
        eprintln!(
            "run={name} steps={} finished={} tokens={tokens} \
             accepted_per_step={per_step:.3} switches={switches}",
            report.steps, report.finished.len()
        );
        Ok(Json::obj(vec![
            ("name", Json::str(name)),
            ("mode", Json::str(mode.name())),
            ("kinds", Json::Arr(
                ks.iter().map(|k| Json::str(k.name())).collect())),
            ("steps", Json::num(report.steps as f64)),
            ("finished", Json::num(report.finished.len() as f64)),
            ("tokens", Json::num(tokens as f64)),
            ("accepted_tokens_per_step", Json::num(per_step)),
            ("switches", Json::num(switches as f64)),
        ]))
    };
    let mut results =
        vec![run("portfolio(auto)".to_string(), SpecMode::Auto, &kinds)?];
    for &k in &kinds {
        results.push(run(format!("single({})", k.name()),
                         SpecMode::Fixed, &[k])?);
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("portfolio")),
        ("trace", Json::str("spec_mixed")),
        ("seed", Json::num(seed as f64)),
        ("portfolio", Json::Arr(
            kinds.iter().map(|k| Json::str(k.name())).collect())),
        ("results", Json::Arr(results)),
    ]);
    let path = "BENCH_portfolio.json";
    std::fs::write(path, format!("{doc}\n"))?;
    eprintln!("wrote {path}");
    Ok(())
}

// ---------------------------------------------------------------- connbench
/// One measured round: a mock-mode server, `n` concurrent streaming
/// clients, then the worker's per-round latency histogram out of `stats`.
/// Returns (mean_s, p50_s, p95_s, rounds).
fn fanin_round(n: usize, max_new: usize, io_threads: usize)
               -> Result<(f64, f64, f64, usize)> {
    let server = Server::start(ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        artifacts: default_artifacts_dir(),
        engine: EngineConfig::default(),
        frontend: FrontendConfig {
            io_threads,
            conn_write_cap: 1024,
            max_conns: n + 16,
            ..FrontendConfig::default()
        },
        // step pacing off: rounds measure pure scheduling + fan-out work
        mock: Some(MockServeConfig { step_delay_us: 0,
                                     ..MockServeConfig::default() }),
        supervisor: SupervisorConfig::default(),
    })?;
    let addr = server.local_addr.to_string();
    let mut joins = Vec::new();
    for i in 0..n {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || -> Result<()> {
            let mut c = Client::connect(&addr)?;
            let prompt = format!("connbench client {i} prompt payload");
            let out = c.generate_stream(i as i64, &prompt, max_new, true,
                                        |_| {})?;
            match out {
                ctcdraft::server::GenerateOutcome::Done(_) => Ok(()),
                other => bail!("client {i}: unexpected outcome {other:?}"),
            }
        }));
    }
    for j in joins {
        j.join().expect("client thread")?;
    }
    let stats = Client::connect(&addr)?.stats_detail()?;
    server.stop();
    let w0 = stats
        .get("workers")
        .as_arr()
        .and_then(|ws| ws.first().cloned())
        .ok_or_else(|| anyhow::anyhow!("stats missing workers[0]"))?;
    let mean = w0.get("round_mean_us").as_f64().unwrap_or(0.0) * 1e-6;
    let p50 = w0.get("round_p50_us").as_f64().unwrap_or(0.0) * 1e-6;
    let p95 = w0.get("round_p95_us").as_f64().unwrap_or(0.0) * 1e-6;
    let rounds = w0.get("steps").as_usize().unwrap_or(0);
    Ok((mean, p50, p95, rounds))
}

/// Measure scheduler-round latency under a small baseline fan-in and a
/// large one, and emit `BENCH_conn_fanin.json` with the per-connection
/// overhead — the check.sh frontend gate: hundreds of multiplexed
/// connections must not put more than a documented ceiling of extra time
/// per connection on a worker's round.
fn cmd_connbench(argv: &[String]) -> Result<()> {
    let cli = Cli::new("ctcdraft connbench",
                       "connection fan-in overhead bench (mock mode)")
        .opt("clients", "fan-in client count", Some("256"))
        .opt("baseline", "baseline client count", Some("4"))
        .opt("max-new", "tokens per request", Some("16"))
        .opt("io-threads", "driver threads (0 = one per core)", Some("0"))
        .flag("smoke", "reduced fan-in for the CI budget");
    let a = parse_args(cli, argv)?;
    let smoke = a.flag("smoke") || bench::smoke_mode();
    let clients = if smoke { 64 } else { a.usize("clients", 256) };
    let baseline = a.usize("baseline", 4).max(1);
    let max_new = a.usize("max-new", 16);
    let io_threads = a.usize("io-threads", 0);

    let (bm, bp50, bp95, brounds) = fanin_round(baseline, max_new, io_threads)?;
    let (fm, fp50, fp95, frounds) = fanin_round(clients, max_new, io_threads)?;
    let overhead = (fm - bm).max(0.0) / clients as f64;
    let mk = |name: &str, mean: f64, p50: f64, p95: f64, iters: usize| {
        bench::BenchResult {
            name: name.to_string(),
            iters,
            mean_s: mean,
            p50_s: p50,
            p95_s: p95,
            total_s: mean * iters as f64,
        }
    };
    let results = vec![
        mk(&format!("conn_round(base x{baseline})"), bm, bp50, bp95, brounds),
        mk(&format!("conn_round(fanin x{clients})"), fm, fp50, fp95, frounds),
        mk("fanin_per_conn_overhead", overhead, overhead, overhead, 1),
    ];
    bench::print_results("connection fan-in (mock serving mode)", &results);
    bench::write_json("conn_fanin", &results)?;
    Ok(())
}

// ---------------------------------------------------------------- shedreplay
/// Seeded, socket-free replay of the bounded-write-queue shed state
/// machine (`server::conn::shed_replay`): producers push frames, a mix of
/// streaming / slow-reader / cancel-storm consumers drain them, and the
/// canonical event log goes to stdout. Same flags MUST print the same
/// bytes — check.sh diffs a double run as the shed determinism gate.
fn cmd_shedreplay(argv: &[String]) -> Result<()> {
    let cli = Cli::new("ctcdraft shedreplay",
                       "deterministic write-queue shed replay")
        .opt("seed", "scenario seed", Some("7"))
        .opt("conns", "simulated connections", Some("24"))
        .opt("cap", "write-queue cap (frames)", Some("8"))
        .opt("rounds", "producer rounds", Some("64"))
        .opt("flaky-frac",
             "share of clients that drop mid-stream and reconnect-and-retry \
              (replay-from-prompt semantics, the client half of failover)",
             Some("0"));
    let a = parse_args(cli, argv)?;
    print!("{}", ctcdraft::server::conn::shed_replay_flaky(
        a.u64("seed", 7),
        a.usize("conns", 24),
        a.usize("cap", 8),
        a.usize("rounds", 64),
        a.f64("flaky-frac", 0.0),
    ));
    Ok(())
}

// ---------------------------------------------------------------- warmup
fn cmd_warmup(argv: &[String]) -> Result<()> {
    let cli = Cli::new("ctcdraft warmup", "precompile all graphs")
        .opt("artifacts", "artifacts directory", None)
        .opt("model", "model name", Some("vic-tiny"));
    let a = parse_args(cli, argv)?;
    let rt = Runtime::load(artifacts_dir(&a))?;
    let t0 = std::time::Instant::now();
    let n = rt.warmup(a.get_or("model", "vic-tiny"))?;
    println!("compiled {n} graphs in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
