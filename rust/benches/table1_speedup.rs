//! Regenerates **Table 1**: average speedup ratio γ and accepted tokens β on
//! MT-bench and GSM8K, for every available base model × speculation method.
//!
//! Paper shape to reproduce (not absolute numbers — different substrate):
//!   * ctc > hydra > medusa > vanilla in both γ and β on MT-bench,
//!   * β for ctc ≳ 3 with a well-fit head,
//!   * β decays as the base model grows (fixed-size draft head),
//!   * on GSM8K ctc stays ahead of medusa.
//!
//! `cargo bench --bench table1_speedup [-- --full]`

use ctcdraft::bench::eval::{engine_for, run_workload};
use ctcdraft::bench::eval_scale;
use ctcdraft::config::Method;
use ctcdraft::metrics::RunSummary;
use ctcdraft::util::render_table;
use ctcdraft::workload;

fn main() {
    let artifacts = ctcdraft::default_artifacts_dir();
    let models = ctcdraft::bench::eval::available_models(&artifacts);
    if models.is_empty() {
        eprintln!("no artifacts — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut json = Vec::new();
    let (per_cat, max_new) = eval_scale();
    let vic_models: Vec<&String> =
        models.iter().filter(|m| m.starts_with("vic")).collect();

    for (wname, qs) in [
        ("MT-bench", workload::mtbench(per_cat, 7)),
        ("GSM8K", workload::gsm8k(per_cat * 8, 7)),
    ] {
        println!("\n### Table 1 — {wname} ({} questions, ≤{max_new} tok) ###",
                 qs.len());
        let mut rows = Vec::new();
        for model in &vic_models {
            let mut engine = match engine_for(&artifacts, model, Method::Vanilla) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("skip {model}: {e:#}");
                    continue;
                }
            };
            let mut vanilla: Option<RunSummary> = None;
            for method in [Method::Vanilla, Method::Medusa, Method::Hydra,
                           Method::Ctc] {
                engine.set_method(method, true);
                let s = run_workload(&mut engine, &qs, max_new)
                    .expect("eval failed")
                    .summary;
                json.push(ctcdraft::bench::result_from_summary(
                    &format!("{wname}/{model}/{}", method.name()), &s));
                let gamma = vanilla.as_ref().map(|v| s.gamma_vs(v)).unwrap_or(1.0);
                rows.push(vec![
                    model.to_string(),
                    engine
                        .runtime()
                        .manifest
                        .models[model.as_str()]
                        .config
                        .analog
                        .clone(),
                    method.name().to_string(),
                    format!("{gamma:.2}x"),
                    format!("{:.2}", s.beta()),
                ]);
                if method == Method::Vanilla {
                    vanilla = Some(s);
                }
            }
        }
        print!("{}", render_table(
            &["model", "analog", "method", "γ", "β"], &rows));
    }
    if let Err(e) = ctcdraft::bench::write_json("table1_speedup", &json) {
        eprintln!("failed to write BENCH_table1_speedup.json: {e}");
    }
    println!("\npaper Table 1 (MT-bench, Vicuna-7B/13B/33B):");
    println!("  vanilla 1.00/1.00/1.00β=1 · medusa 2.13x,2.58 | 1.97x,2.60 | 1.93x,2.55");
    println!("  hydra 2.36x,3.04 | 2.17x,3.06 | 2.15x,2.95 · ctc 2.78x,3.56 | 2.52x,3.51 | 2.20x,3.53");
}
