//! Regenerates **Figure 3**: percentage of inference time spent in each
//! pipeline stage, CTC-drafter vs Medusa.
//!
//! Paper numbers: CTC-drafter — draft model 14.93%, CTC transform 5.36%,
//! base model + others the rest; Medusa — draft model 3.71%. The shape to
//! reproduce: CTC spends visibly more on drafting/transform than Medusa, yet
//! the base model still dominates, so the better acceptance rate wins
//! overall.
//!
//! `cargo bench --bench fig3_time_breakdown [-- --full]`

use ctcdraft::bench::eval::{engine_for, run_workload};
use ctcdraft::bench::eval_scale;
use ctcdraft::config::Method;
use ctcdraft::util::render_table;
use ctcdraft::workload;

fn pie(label: &str, pct: f64) -> String {
    let blocks = "▒".repeat((pct / 2.0).round() as usize);
    format!("  {label:13} {pct:5.2}% {blocks}")
}

fn main() {
    let artifacts = ctcdraft::default_artifacts_dir();
    let model = "vic-tiny";
    let (per_cat, max_new) = eval_scale();
    let qs = workload::mtbench(per_cat, 17);
    println!("### Figure 3 — time breakdown on {model} ({} questions) ###\n",
             qs.len());

    let mut engine = engine_for(&artifacts, model, Method::Ctc)
        .expect("engine (run `make artifacts`)");

    let mut rows = Vec::new();
    let mut json = Vec::new();
    for method in [Method::Ctc, Method::Medusa] {
        engine.set_method(method, true);
        let s = run_workload(&mut engine, &qs, max_new).unwrap().summary;
        json.push(ctcdraft::bench::result_from_summary(method.name(), &s));
        let (base, draft, transform, other) = s.breakdown.percentages();
        println!("{}:", method.name());
        println!("{}", pie("base model", base));
        println!("{}", pie("draft model", draft));
        println!("{}", pie("ctc transform", transform));
        println!("{}\n", pie("others", other));
        rows.push(vec![
            method.name().to_string(),
            format!("{base:.2}%"),
            format!("{draft:.2}%"),
            format!("{transform:.2}%"),
            format!("{other:.2}%"),
        ]);
    }
    print!("{}", render_table(
        &["method", "base model", "draft model", "ctc transform", "others"],
        &rows));
    if let Err(e) = ctcdraft::bench::write_json("fig3_time_breakdown", &json) {
        eprintln!("failed to write BENCH_fig3_time_breakdown.json: {e}");
    }
    println!("\npaper: ctc — draft 14.93%, transform 5.36%; medusa — draft 3.71%");
}
