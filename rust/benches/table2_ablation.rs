//! Regenerates **Table 2**: model-structure ablation — {linear+CE vs
//! transformer+CTC} × {Medusa verify vs CTC verify} on MT-bench, Vicuna-7B
//! analog. The paper's finding: the CTC head helps only together with the
//! CTC transform (β 3.02→3.56, γ 2.25→2.78); without the transform, blanks
//! and repeats spoil the candidates.
//!
//! `cargo bench --bench table2_ablation [-- --full]`

use ctcdraft::bench::eval::{engine_for, run_workload};
use ctcdraft::bench::eval_scale;
use ctcdraft::config::Method;
use ctcdraft::util::render_table;
use ctcdraft::workload;

fn main() {
    let artifacts = ctcdraft::default_artifacts_dir();
    let model = "vic-tiny";
    let (per_cat, max_new) = eval_scale();
    let qs = workload::mtbench(per_cat, 11);
    println!("### Table 2 — ablation on {model} ({} questions) ###\n", qs.len());

    let mut engine = engine_for(&artifacts, model, Method::Vanilla)
        .expect("engine (run `make artifacts`)");
    let vanilla = run_workload(&mut engine, &qs, max_new).unwrap().summary;

    let variants: [(&str, Method, bool); 3] = [
        ("Linear layer + CE loss | Medusa verify", Method::Medusa, true),
        ("Transformer + CTC loss | Medusa verify", Method::Ctc, false),
        ("Transformer + CTC loss | CTC verify", Method::Ctc, true),
    ];
    let mut rows = Vec::new();
    let mut json = vec![ctcdraft::bench::result_from_summary("vanilla", &vanilla)];
    for (label, method, transform) in variants {
        engine.set_method(method, transform);
        let s = run_workload(&mut engine, &qs, max_new).unwrap().summary;
        json.push(ctcdraft::bench::result_from_summary(label, &s));
        rows.push(vec![
            label.to_string(),
            format!("{:.2}x", s.gamma_vs(&vanilla)),
            format!("{:.2}", s.beta()),
        ]);
    }
    if let Err(e) = ctcdraft::bench::write_json("table2_ablation", &json) {
        eprintln!("failed to write BENCH_table2_ablation.json: {e}");
    }
    print!("{}", render_table(&["draft module | verify", "γ", "β"], &rows));
    println!("\npaper: 2.13x,2.58 · 2.25x,3.02 · 2.78x,3.56");
}
