//! Regenerates **Figure 4** (appendix): CTC-drafter γ and β across base-model
//! families and sizes — Vicuna analogs *and* LLaMA-2-Chat analogs — on both
//! MT-bench and GSM8K.
//!
//! Paper shape: the method transfers across families with only slight
//! degradation; for the lc2 family, moving from the 7B to the 13B analog
//! does not hurt draft quality.
//!
//! `cargo bench --bench fig4_model_families [-- --full]`

use ctcdraft::bench::eval::{available_models, engine_for, run_workload};
use ctcdraft::bench::eval_scale;
use ctcdraft::config::Method;
use ctcdraft::util::render_table;
use ctcdraft::workload;

fn main() {
    let artifacts = ctcdraft::default_artifacts_dir();
    let models = available_models(&artifacts);
    if models.is_empty() {
        eprintln!("no artifacts — run `make artifacts` first");
        std::process::exit(1);
    }
    let (per_cat, max_new) = eval_scale();
    let mut json = Vec::new();

    for (wname, qs) in [
        ("MT-bench", workload::mtbench(per_cat, 19)),
        ("GSM8K", workload::gsm8k(per_cat * 8, 19)),
    ] {
        println!("\n### Figure 4 — {wname}: CTC-drafter across model families ###\n");
        let mut rows = Vec::new();
        let mut bars = Vec::new();
        for model in &models {
            let mut engine = match engine_for(&artifacts, model, Method::Vanilla) {
                Ok(e) => e,
                Err(e) => {
                    eprintln!("skip {model}: {e:#}");
                    continue;
                }
            };
            let analog = engine.runtime().manifest.models[model.as_str()]
                .config
                .analog
                .clone();
            let vanilla = run_workload(&mut engine, &qs, max_new).unwrap().summary;
            engine.set_method(Method::Ctc, true);
            let s = run_workload(&mut engine, &qs, max_new).unwrap().summary;
            json.push(ctcdraft::bench::result_from_summary(
                &format!("{wname}/{model}/ctc"), &s));
            let gamma = s.gamma_vs(&vanilla);
            rows.push(vec![
                model.clone(),
                analog.clone(),
                format!("{gamma:.2}x"),
                format!("{:.2}", s.beta()),
            ]);
            bars.push((analog, gamma, s.beta()));
        }
        print!("{}", render_table(&["model", "analog", "γ", "β"], &rows));
        println!("\nγ bars:");
        for (analog, gamma, _) in &bars {
            println!("  {analog:18} {gamma:4.2} {}",
                     "█".repeat((gamma * 10.0).round() as usize));
        }
        println!("β bars:");
        for (analog, _, beta) in &bars {
            println!("  {analog:18} {beta:4.2} {}",
                     "█".repeat((beta * 8.0).round() as usize));
        }
    }
    if let Err(e) = ctcdraft::bench::write_json("fig4_model_families", &json) {
        eprintln!("failed to write BENCH_fig4_model_families.json: {e}");
    }
    println!("\npaper Fig 4: γ≈2.2–2.8 and β≈3.4–3.6 across Vicuna-{{7,13,33}}B \
              and LLaMA-2-Chat-{{7,13}}B, both datasets");
}
