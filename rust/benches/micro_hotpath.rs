//! Micro-benchmarks of the L3 hot path — the per-step cost centers the §Perf
//! pass optimizes: CTC transform, tree/mask construction, KV batch assembly,
//! tokenizer, the PJRT step/draft calls, and the rust CTC DP vs the exported
//! Pallas ctc_score kernel.
//!
//! `cargo bench --bench micro_hotpath`

use ctcdraft::bench::{bench, print_results};
use ctcdraft::config::Method;
use ctcdraft::ctc;
use ctcdraft::drafters::CandidatePath;
use ctcdraft::runtime::tensor::Tensor;
use ctcdraft::runtime::Runtime;
use ctcdraft::testkit::gen;
use ctcdraft::tree::TokenTree;
use ctcdraft::util::rng::Rng;

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(0);

    // ---------- pure host-side pieces (no runtime needed)
    let slots = 8;
    let vp1 = 513;
    let blank = (vp1 - 1) as i32;
    let logp = gen::logp_matrix(&mut rng, slots, vp1);
    let raw: Vec<CandidatePath> = (0..12)
        .map(|i| CandidatePath {
            tokens: (0..slots).map(|_| rng.below(vp1) as i32).collect(),
            score: -(i as f32),
        })
        .collect();
    results.push(bench("ctc_transform(12 paths)", 200, 0.3, || {
        let out = ctc::transform_paths(&raw, &logp, slots, vp1, blank, 6);
        std::hint::black_box(out);
    }));

    results.push(bench("ctc_marginal_nll(U=6)", 500, 0.3, || {
        let nll = ctc::ctc_marginal_nll(&logp, slots, vp1, &[5, 9, 3, 2, 8, 1]);
        std::hint::black_box(nll);
    }));

    let paths: Vec<CandidatePath> = (0..12)
        .map(|i| CandidatePath {
            tokens: (0..6).map(|_| rng.below(512) as i32).collect(),
            score: -(i as f32) * 0.3,
        })
        .collect();
    results.push(bench("tree_from_paths(12x6)", 500, 0.3, || {
        let t = TokenTree::from_paths(7, &paths, 32);
        std::hint::black_box(t);
    }));

    let tree = TokenTree::from_paths(7, &paths, 32);
    results.push(bench("tree_attention_bias(32x416)", 500, 0.3, || {
        let b = tree.attention_bias(128, 384, 32);
        std::hint::black_box(b);
    }));

    // ---------- runtime-backed pieces (need artifacts)
    let artifacts = ctcdraft::default_artifacts_dir();
    match Runtime::load(&artifacts) {
        Ok(rt) => {
            let model = rt.manifest.models.keys().next().cloned();
            if let Some(model) = model {
                bench_runtime(&rt, &model, &mut results);
            }
            bench_ctc_kernel(&rt, &mut results);
        }
        Err(e) => eprintln!("(skipping runtime benches: {e:#})"),
    }

    // ---------- end-to-end single step
    if let Ok(rt) = Runtime::load(&artifacts) {
        if rt.has_model("vic-tiny") {
            use ctcdraft::config::EngineConfig;
            use ctcdraft::engine::Engine;
            let mut engine = Engine::new(rt, EngineConfig {
                model: "vic-tiny".into(),
                method: Method::Ctc,
                ..EngineConfig::default()
            }).unwrap();
            let prompt = engine.format_prompt("What is 12 times 4?");
            engine.admit(&prompt, 10_000).unwrap();
            results.push(bench("engine_spec_step(b=1)", 20, 1.0, || {
                if engine.n_active() == 0 {
                    // sequence finished (EOS / capacity): re-admit so every
                    // iteration measures a real speculative step
                    engine.admit(&prompt, 10_000).unwrap();
                }
                let _ = engine.step().unwrap();
            }));
        }
    }

    print_results("micro hot-path", &results);
}

fn bench_runtime(rt: &Runtime, model: &str,
                 results: &mut Vec<ctcdraft::bench::BenchResult>) {
    let c = rt.manifest.constants.clone();
    let cfg = rt.manifest.model(model).unwrap().config.clone();
    let (l, h, dh, d) = (cfg.layers, cfg.n_heads, c.head_dim, cfg.d_model);
    let cache_shape = [l, 1, c.lmax, h, dh];

    // decode step (n=1)
    let mut bias = vec![-1e9f32; c.lmax + 1];
    bias[c.lmax] = 0.0;
    let args = vec![
        Tensor::zeros_f32(&cache_shape),
        Tensor::zeros_f32(&cache_shape),
        Tensor::from_i32(&[1, 1], vec![5]),
        Tensor::from_i32(&[1, 1], vec![0]),
        Tensor::from_f32(&[1, 1, c.lmax + 1], bias),
    ];
    results.push(bench(&format!("step_graph_{model}_b1_n1"), 20, 1.0, || {
        let out = rt.run_step(model, 1, 1, &args).unwrap();
        std::hint::black_box(out);
    }));

    // verify step (n=tree_n)
    let n = c.tree_n;
    let mut bias = vec![-1e9f32; n * (c.lmax + n)];
    for i in 0..n {
        bias[i * (c.lmax + n) + c.lmax + i] = 0.0;
    }
    let args = vec![
        Tensor::zeros_f32(&cache_shape),
        Tensor::zeros_f32(&cache_shape),
        Tensor::from_i32(&[1, n], vec![5; n]),
        Tensor::from_i32(&[1, n], vec![0; n]),
        Tensor::from_f32(&[1, n, c.lmax + n], bias),
    ];
    results.push(bench(&format!("step_graph_{model}_b1_n{n}"), 10, 1.0, || {
        let out = rt.run_step(model, 1, n, &args).unwrap();
        std::hint::black_box(out);
    }));

    // ctc draft graph
    let args = vec![
        Tensor::zeros_f32(&[1, c.hidden_win, d]),
        Tensor::from_i32(&[1], vec![c.hidden_win as i32]),
    ];
    results.push(bench(&format!("draft_ctc_{model}_b1"), 20, 1.0, || {
        let out = rt.run_draft(model, "ctc", 1, &args).unwrap();
        std::hint::black_box(out);
    }));
}

fn bench_ctc_kernel(rt: &Runtime, results: &mut Vec<ctcdraft::bench::BenchResult>) {
    let c = rt.manifest.constants.clone();
    let b = c.ctc_score_batch;
    let vp1 = c.vocab_size + 1;
    let kname = format!("ctc_score_b{b}");
    if !rt.manifest.kernels.contains_key(&kname) {
        return;
    }
    let mut rng = Rng::new(3);
    let logp = gen::logp_matrix(&mut rng, b * c.draft_slots, vp1);
    let targets: Vec<i32> = (0..b * c.ctc_target_u)
        .map(|_| rng.below(c.vocab_size) as i32)
        .collect();
    let args = vec![
        Tensor::from_f32(&[b, c.draft_slots, vp1], logp.clone()),
        Tensor::from_i32(&[b, c.ctc_target_u], targets.clone()),
        Tensor::from_i32(&[b], vec![c.ctc_target_u as i32; b]),
    ];
    results.push(bench("ctc_score_kernel(pallas,b16)", 20, 1.0, || {
        let out = rt.run_kernel(&kname, &args).unwrap();
        std::hint::black_box(out);
    }));
    // the equivalent rust DP for the same batch
    results.push(bench("ctc_score_rust_dp(b16)", 50, 0.5, || {
        for i in 0..b {
            let lp = &logp[i * c.draft_slots * vp1..(i + 1) * c.draft_slots * vp1];
            let tgt = &targets[i * c.ctc_target_u..(i + 1) * c.ctc_target_u];
            std::hint::black_box(ctc::ctc_marginal_nll(lp, c.draft_slots, vp1, tgt));
        }
    }));
}
