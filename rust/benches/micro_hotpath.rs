//! Micro-benchmarks of the L3 hot path — the per-step cost centers the §Perf
//! pass optimizes: CTC transform, tree/mask construction, KV batch assembly,
//! tokenizer, the PJRT step/draft calls, and the rust CTC DP vs the exported
//! Pallas ctc_score kernel.
//!
//! PR 3 measures the allocating seed implementations (faithfully copied into
//! the `legacy` module below) against the arena/scratch hot path, including
//! the combined `hotpath_round(*)` pair — one full draft→verify host round
//! (beam search → tree build → token/pos/bias assembly → KV commit+gather).
//! Results also land in `BENCH_micro_hotpath.json` (see `bench::write_json`)
//! so the perf trajectory is tracked across PRs.
//!
//! `cargo bench --bench micro_hotpath` (`-- --smoke` for the CI-fast mode:
//! minimal iterations, runtime-backed measurements skipped).

use ctcdraft::bench::{self, bench, print_results};
use ctcdraft::config::Method;
use ctcdraft::ctc;
use ctcdraft::drafters::{CandidatePath, PathSet};
use ctcdraft::kvcache::SeqCache;
use ctcdraft::runtime::tensor::Tensor;
use ctcdraft::runtime::Runtime;
use ctcdraft::testkit::gen;
use ctcdraft::tree::TokenTree;
use ctcdraft::util::rng::Rng;

/// The pre-PR-3 (seed) implementations, copied verbatim so one bench run
/// records both sides of the before/after comparison.
mod legacy {
    use std::collections::HashMap;

    use ctcdraft::drafters::CandidatePath;

    pub const NEG_INF: f32 = -1e9;

    fn logaddexp(a: f32, b: f32) -> f32 {
        let m = a.max(b);
        if m <= NEG_INF / 2.0 {
            return NEG_INF;
        }
        m + ((a - m).exp() + (b - m).exp()).ln()
    }

    /// Seed `ctc::prefix_beam_search`: HashMap-keyed beams, fresh
    /// allocations per slot per round.
    pub fn prefix_beam_search(slot_logp: &[f32], slots: usize, vp1: usize,
                              sym_topk: usize, beam_width: usize,
                              max_len: usize) -> Vec<CandidatePath> {
        let blank = vp1 - 1;
        let mut beams: HashMap<Vec<i32>, (f32, f32)> = HashMap::new();
        beams.insert(Vec::new(), (0.0, NEG_INF));
        for t in 0..slots {
            let row = &slot_logp[t * vp1..(t + 1) * vp1];
            let picks = ctcdraft::drafters::topk(row, sym_topk.min(vp1));
            let mut next: HashMap<Vec<i32>, (f32, f32)> = HashMap::new();
            let bump = |map: &mut HashMap<Vec<i32>, (f32, f32)>,
                        key: Vec<i32>, is_blank_end: bool, lp: f32| {
                let e = map.entry(key).or_insert((NEG_INF, NEG_INF));
                if is_blank_end {
                    e.0 = logaddexp(e.0, lp);
                } else {
                    e.1 = logaddexp(e.1, lp);
                }
            };
            for (prefix, &(p_b, p_nb)) in &beams {
                for &s in &picks {
                    let lp = row[s];
                    if s == blank {
                        bump(&mut next, prefix.clone(), true,
                             logaddexp(p_b, p_nb) + lp);
                    } else if prefix.last() == Some(&(s as i32)) {
                        bump(&mut next, prefix.clone(), false, p_nb + lp);
                        if prefix.len() < max_len {
                            let mut ext = prefix.clone();
                            ext.push(s as i32);
                            bump(&mut next, ext, false, p_b + lp);
                        }
                    } else if prefix.len() < max_len {
                        let mut ext = prefix.clone();
                        ext.push(s as i32);
                        bump(&mut next, ext, false, logaddexp(p_b, p_nb) + lp);
                    }
                }
            }
            let mut entries: Vec<(Vec<i32>, (f32, f32))> =
                next.into_iter().collect();
            entries.sort_by(|a, b| {
                logaddexp(b.1 .0, b.1 .1)
                    .partial_cmp(&logaddexp(a.1 .0, a.1 .1))
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            entries.truncate(beam_width);
            beams = entries.into_iter().collect();
        }
        let mut out: Vec<CandidatePath> = beams
            .into_iter()
            .filter(|(p, _)| !p.is_empty())
            .map(|(tokens, (p_b, p_nb))| CandidatePath {
                tokens,
                score: logaddexp(p_b, p_nb),
            })
            .collect();
        out.sort_by(|a, b| {
            b.score.partial_cmp(&a.score).unwrap_or(std::cmp::Ordering::Equal)
        });
        out
    }

    /// Seed `tree::TokenTree`: AoS node vec, O(nodes) child scan, ancestry
    /// re-derived per bias row, fresh Vec per padded/bias call.
    #[derive(Clone)]
    pub struct TreeNode {
        pub token: i32,
        pub parent: Option<usize>,
        pub depth: usize,
        pub score: f32,
    }

    pub struct Tree {
        pub nodes: Vec<TreeNode>,
    }

    impl Tree {
        pub fn from_paths(base_token: i32, paths: &[CandidatePath],
                          max_nodes: usize) -> Tree {
            let mut tree = Tree {
                nodes: vec![TreeNode {
                    token: base_token,
                    parent: None,
                    depth: 0,
                    score: 0.0,
                }],
            };
            let mut order: Vec<usize> = (0..paths.len()).collect();
            order.sort_by(|&a, &b| {
                paths[b].score.partial_cmp(&paths[a].score)
                    .unwrap_or(std::cmp::Ordering::Equal)
            });
            for pi in order {
                let path = &paths[pi];
                let mut cur = 0usize;
                for (d, &tok) in path.tokens.iter().enumerate() {
                    let child = tree.nodes.iter().position(|n| {
                        n.parent == Some(cur) && n.token == tok
                    });
                    match child {
                        Some(c) => cur = c,
                        None => {
                            if tree.nodes.len() >= max_nodes {
                                break;
                            }
                            tree.nodes.push(TreeNode {
                                token: tok,
                                parent: Some(cur),
                                depth: d + 1,
                                score: path.score,
                            });
                            cur = tree.nodes.len() - 1;
                        }
                    }
                }
            }
            tree
        }

        pub fn ancestry(&self, mut i: usize) -> Vec<usize> {
            let mut chain = vec![i];
            while let Some(p) = self.nodes[i].parent {
                chain.push(p);
                i = p;
            }
            chain.reverse();
            chain
        }

        pub fn tokens_padded(&self, n_slots: usize, pad: i32) -> Vec<i32> {
            let mut out = vec![pad; n_slots];
            for (i, n) in self.nodes.iter().enumerate().take(n_slots) {
                out[i] = n.token;
            }
            out
        }

        pub fn positions_padded(&self, base_pos: usize, n_slots: usize)
                                -> Vec<i32> {
            let mut out = vec![base_pos as i32; n_slots];
            for (i, n) in self.nodes.iter().enumerate().take(n_slots) {
                out[i] = (base_pos + n.depth) as i32;
            }
            out
        }

        pub fn attention_bias(&self, cache_len: usize, lmax: usize,
                              n_slots: usize) -> Vec<f32> {
            let m = lmax + n_slots;
            let mut bias = vec![NEG_INF; n_slots * m];
            for i in 0..n_slots {
                let row = &mut bias[i * m..(i + 1) * m];
                if i < self.nodes.len() {
                    row[..cache_len].fill(0.0);
                    for a in self.ancestry(i) {
                        row[lmax + a] = 0.0;
                    }
                } else {
                    row[lmax + i] = 0.0;
                }
            }
            bias
        }
    }
}

fn main() {
    let smoke = bench::smoke_mode();
    let (it, secs) = if smoke { (10, 0.0) } else { (200, 0.3) };
    let mut results = Vec::new();
    let mut rng = Rng::new(0);

    // ---------- pure host-side pieces (no runtime needed)
    let slots = 8;
    let vp1 = 513;
    let blank = (vp1 - 1) as i32;
    let logp = gen::logp_matrix(&mut rng, slots, vp1);
    let raw: Vec<CandidatePath> = (0..12)
        .map(|i| CandidatePath {
            tokens: (0..slots).map(|_| rng.below(vp1) as i32).collect(),
            score: -(i as f32),
        })
        .collect();
    results.push(bench("ctc_transform(12 paths)", it, secs, || {
        let out = ctc::transform_paths(&raw, &logp, slots, vp1, blank, 6);
        std::hint::black_box(out);
    }));
    let mut tf_scratch = ctc::TransformScratch::default();
    let mut tf_out = PathSet::with_capacity(12, 6);
    results.push(bench("ctc_transform(scratch)", it, secs, || {
        ctc::transform_paths_into(
            raw.iter().map(|p| (p.tokens.as_slice(), p.score)),
            &logp, slots, vp1, blank, 6, &mut tf_scratch, &mut tf_out);
        std::hint::black_box(tf_out.len());
    }));

    results.push(bench("ctc_marginal_nll(U=6)", it.max(100), secs, || {
        let nll = ctc::ctc_marginal_nll(&logp, slots, vp1, &[5, 9, 3, 2, 8, 1]);
        std::hint::black_box(nll);
    }));

    // beam search: seed HashMap implementation vs PR-3 arena implementation
    results.push(bench("prefix_beam(hashmap,legacy)", it, secs, || {
        let out = legacy::prefix_beam_search(&logp, slots, vp1, 8, 16, 6);
        std::hint::black_box(out);
    }));
    let mut beam = ctc::BeamScratch::new();
    let mut beam_out = PathSet::with_capacity(16, 6);
    results.push(bench("prefix_beam(arena)", it, secs, || {
        ctc::prefix_beam_search_into(&mut beam, &logp, slots, vp1, 8, 16, 6,
                                     &mut beam_out);
        std::hint::black_box(beam_out.len());
    }));

    let paths: Vec<CandidatePath> = (0..12)
        .map(|i| CandidatePath {
            tokens: (0..6).map(|_| rng.below(512) as i32).collect(),
            score: -(i as f32) * 0.3,
        })
        .collect();
    results.push(bench("tree_from_paths(12x6,legacy)", it, secs, || {
        let t = legacy::Tree::from_paths(7, &paths, 32);
        std::hint::black_box(t.nodes.len());
    }));
    let mut arena = TokenTree::with_capacity(32);
    results.push(bench("tree_rebuild(arena,12x6)", it, secs, || {
        arena.rebuild(7, paths.iter().map(|p| (p.tokens.as_slice(), p.score)),
                      32);
        std::hint::black_box(arena.len());
    }));

    let ltree = legacy::Tree::from_paths(7, &paths, 32);
    results.push(bench("tree_attention_bias(32x416,legacy)", it, secs, || {
        let b = ltree.attention_bias(128, 384, 32);
        std::hint::black_box(b);
    }));
    let tree = TokenTree::from_paths(7, &paths, 32);
    let mut bias_buf = vec![0f32; 32 * 416];
    results.push(bench("tree_write_bias(32x416,arena)", it, secs, || {
        tree.write_bias(&mut bias_buf, 128, 384, 32);
        std::hint::black_box(bias_buf[0]);
    }));

    // ---------- the combined draft→verify host round (the PR-3 headline)
    bench_hotpath_round(&mut results, smoke);

    if !smoke {
        // ---------- runtime-backed pieces (need artifacts)
        let artifacts = ctcdraft::default_artifacts_dir();
        match Runtime::load(&artifacts) {
            Ok(rt) => {
                let model = rt.manifest.models.keys().next().cloned();
                if let Some(model) = model {
                    bench_runtime(&rt, &model, &mut results);
                }
                bench_ctc_kernel(&rt, &mut results);
            }
            Err(e) => eprintln!("(skipping runtime benches: {e:#})"),
        }

        // ---------- end-to-end single step
        if let Ok(rt) = Runtime::load(&artifacts) {
            if rt.has_model("vic-tiny") {
                use ctcdraft::config::EngineConfig;
                use ctcdraft::engine::Engine;
                let mut engine = Engine::new(rt, EngineConfig {
                    model: "vic-tiny".into(),
                    method: Method::Ctc,
                    ..EngineConfig::default()
                }).unwrap();
                let prompt = engine.format_prompt("What is 12 times 4?");
                engine.admit(&prompt, 10_000).unwrap();
                results.push(bench("engine_spec_step(b=1)", 20, 1.0, || {
                    if engine.n_active() == 0 {
                        // sequence finished (EOS / capacity): re-admit so
                        // every iteration measures a real speculative step
                        engine.admit(&prompt, 10_000).unwrap();
                    }
                    let _ = engine.step().unwrap();
                }));
            }
        }
    }

    print_results("micro hot-path", &results);
    if let Err(e) = bench::write_json("micro_hotpath", &results) {
        eprintln!("failed to write BENCH_micro_hotpath.json: {e}");
        std::process::exit(1);
    }
}

/// One full draft→verify host round for a single sequence — beam search,
/// tree build, token/pos/bias assembly, KV commit and batch gather — in the
/// seed (allocating, full-recopy) form vs the PR-3 (arena, incremental)
/// form, over the same inputs. The acceptance bar is the mean-time ratio
/// between these two entries.
fn bench_hotpath_round(results: &mut Vec<ctcdraft::bench::BenchResult>,
                       smoke: bool) {
    let (it, secs) = if smoke { (10, 0.0) } else { (150, 0.5) };
    let (slots, vp1) = (8usize, 513usize);
    let (layers, heads, head_dim, lmax) = (4usize, 2usize, 32usize, 384usize);
    let re = heads * head_dim;
    let n_slots = 32usize;
    let mut rng = Rng::new(7);
    // a rotation of slot distributions so rounds differ but both variants
    // see the identical workload
    let logps: Vec<Vec<f32>> = (0..8)
        .map(|_| gen::logp_matrix(&mut rng, slots, vp1))
        .collect();
    // batch-shaped fake verify output [L, 1, N, H, Dh]
    let kv_src: Vec<f32> = (0..layers * n_slots * re)
        .map(|i| (i % 97) as f32 * 0.25)
        .collect();
    let picks = [0usize, 1, 2];

    // ---- legacy: per-round Vecs, staging copy, full lmax re-gather
    let mut cache = SeqCache::new(layers, lmax, heads, head_dim);
    let mut bk = vec![0f32; layers * lmax * re];
    let mut bv = vec![0f32; layers * lmax * re];
    let mut i = 0usize;
    results.push(bench("hotpath_round(legacy)", it, secs, || {
        let lp = &logps[i % logps.len()];
        i += 1;
        let paths = legacy::prefix_beam_search(lp, slots, vp1, 8, 16, 6);
        let tree = legacy::Tree::from_paths(7, &paths, n_slots);
        let tokens = tree.tokens_padded(n_slots, 0);
        let pos = tree.positions_padded(cache.len, n_slots);
        let bias = tree.attention_bias(cache.len, lmax, n_slots);
        std::hint::black_box((&tokens, &pos, &bias));
        // seed engine: slice the batch output into per-seq staging buffers
        let mut k_slice = vec![0f32; layers * n_slots * re];
        let mut v_slice = vec![0f32; layers * n_slots * re];
        for l in 0..layers {
            let src = l * n_slots * re;
            k_slice[src..src + n_slots * re]
                .copy_from_slice(&kv_src[src..src + n_slots * re]);
            v_slice[src..src + n_slots * re]
                .copy_from_slice(&kv_src[src..src + n_slots * re]);
        }
        if cache.len + picks.len() + n_slots >= lmax {
            cache.truncate(0);
        }
        cache.append_selected(&k_slice, &v_slice, n_slots, &picks).unwrap();
        cache.copy_into_batch(&mut bk, &mut bv, 0, 1); // full re-copy
        std::hint::black_box(bk[0]);
    }));

    // ---- arena: reused scratch, direct batch commit, incremental gather
    let mut beam = ctc::BeamScratch::new();
    let mut path_set = PathSet::with_capacity(16, 6);
    let mut tree = TokenTree::with_capacity(n_slots);
    let mut tokens = vec![0i32; n_slots];
    let mut pos = vec![0i32; n_slots];
    let mut bias = vec![0f32; n_slots * (lmax + n_slots)];
    let mut cache2 = SeqCache::new(layers, lmax, heads, head_dim);
    let mut bk2 = vec![0f32; layers * lmax * re];
    let mut bv2 = vec![0f32; layers * lmax * re];
    let mut synced = 0usize;
    let mut j = 0usize;
    results.push(bench("hotpath_round(scratch)", it, secs, || {
        let lp = &logps[j % logps.len()];
        j += 1;
        ctc::prefix_beam_search_into(&mut beam, lp, slots, vp1, 8, 16, 6,
                                     &mut path_set);
        tree.rebuild(7, path_set.iter_sorted(), n_slots);
        tree.write_tokens(&mut tokens, 0);
        tree.write_positions(&mut pos, cache2.len);
        tree.write_bias(&mut bias, cache2.len, lmax, n_slots);
        std::hint::black_box((&tokens, &pos, &bias));
        if cache2.len + picks.len() + n_slots >= lmax {
            cache2.truncate(0);
            synced = 0;
        }
        cache2
            .append_from_batch(&kv_src, &kv_src, 1, 0, n_slots, &picks)
            .unwrap();
        cache2.copy_new_into_batch(&mut bk2, &mut bv2, 0, 1, synced);
        synced = cache2.len;
        std::hint::black_box(bk2[0]);
    }));
}

fn bench_runtime(rt: &Runtime, model: &str,
                 results: &mut Vec<ctcdraft::bench::BenchResult>) {
    let c = rt.manifest.constants.clone();
    let cfg = rt.manifest.model(model).unwrap().config.clone();
    let (l, h, dh, d) = (cfg.layers, cfg.n_heads, c.head_dim, cfg.d_model);
    let cache_shape = [l, 1, c.lmax, h, dh];

    // decode step (n=1)
    let mut bias = vec![-1e9f32; c.lmax + 1];
    bias[c.lmax] = 0.0;
    let args = vec![
        Tensor::zeros_f32(&cache_shape),
        Tensor::zeros_f32(&cache_shape),
        Tensor::from_i32(&[1, 1], vec![5]),
        Tensor::from_i32(&[1, 1], vec![0]),
        Tensor::from_f32(&[1, 1, c.lmax + 1], bias),
    ];
    results.push(bench(&format!("step_graph_{model}_b1_n1"), 20, 1.0, || {
        let out = rt.run_step(model, 1, 1, &args).unwrap();
        std::hint::black_box(out);
    }));

    // verify step (n=tree_n)
    let n = c.tree_n;
    let mut bias = vec![-1e9f32; n * (c.lmax + n)];
    for i in 0..n {
        bias[i * (c.lmax + n) + c.lmax + i] = 0.0;
    }
    let args = vec![
        Tensor::zeros_f32(&cache_shape),
        Tensor::zeros_f32(&cache_shape),
        Tensor::from_i32(&[1, n], vec![5; n]),
        Tensor::from_i32(&[1, n], vec![0; n]),
        Tensor::from_f32(&[1, n, c.lmax + n], bias),
    ];
    results.push(bench(&format!("step_graph_{model}_b1_n{n}"), 10, 1.0, || {
        let out = rt.run_step(model, 1, n, &args).unwrap();
        std::hint::black_box(out);
    }));

    // ctc draft graph
    let args = vec![
        Tensor::zeros_f32(&[1, c.hidden_win, d]),
        Tensor::from_i32(&[1], vec![c.hidden_win as i32]),
    ];
    results.push(bench(&format!("draft_ctc_{model}_b1"), 20, 1.0, || {
        let out = rt.run_draft(model, "ctc", 1, &args).unwrap();
        std::hint::black_box(out);
    }));
}

fn bench_ctc_kernel(rt: &Runtime, results: &mut Vec<ctcdraft::bench::BenchResult>) {
    let c = rt.manifest.constants.clone();
    let b = c.ctc_score_batch;
    let vp1 = c.vocab_size + 1;
    let kname = format!("ctc_score_b{b}");
    if !rt.manifest.kernels.contains_key(&kname) {
        return;
    }
    let mut rng = Rng::new(3);
    let logp = gen::logp_matrix(&mut rng, b * c.draft_slots, vp1);
    let targets: Vec<i32> = (0..b * c.ctc_target_u)
        .map(|_| rng.below(c.vocab_size) as i32)
        .collect();
    let args = vec![
        Tensor::from_f32(&[b, c.draft_slots, vp1], logp.clone()),
        Tensor::from_i32(&[b, c.ctc_target_u], targets.clone()),
        Tensor::from_i32(&[b], vec![c.ctc_target_u as i32; b]),
    ];
    results.push(bench("ctc_score_kernel(pallas,b16)", 20, 1.0, || {
        let out = rt.run_kernel(&kname, &args).unwrap();
        std::hint::black_box(out);
    }));
    // the equivalent rust DP for the same batch, with scratch reuse
    let mut dp = ctc::DpScratch::default();
    results.push(bench("ctc_score_rust_dp(b16)", 50, 0.5, || {
        for i in 0..b {
            let lp = &logp[i * c.draft_slots * vp1..(i + 1) * c.draft_slots * vp1];
            let tgt = &targets[i * c.ctc_target_u..(i + 1) * c.ctc_target_u];
            std::hint::black_box(ctc::ctc_marginal_nll_with(
                &mut dp, lp, c.draft_slots, vp1, tgt));
        }
    }));
}
