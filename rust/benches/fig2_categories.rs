//! Regenerates **Figure 2**: average accepted tokens per decoding step (β)
//! across the 8 MT-bench question categories, for CTC-drafter vs Medusa vs
//! the vanilla baseline (β=1 by construction).
//!
//! Paper shape: coding highest for both speculative methods (regular,
//! logical text), roleplay comparatively weak for CTC-drafter.
//!
//! `cargo bench --bench fig2_categories [-- --full]`

use ctcdraft::bench::eval::{engine_for, run_workload};
use ctcdraft::bench::eval_scale;
use ctcdraft::config::Method;
use ctcdraft::util::render_table;
use ctcdraft::workload::{self, CATEGORIES};

fn main() {
    let artifacts = ctcdraft::default_artifacts_dir();
    let model = "vic-tiny";
    let (per_cat, max_new) = eval_scale();
    let qs = workload::mtbench(per_cat, 13);
    println!("### Figure 2 — per-category β on {model} \
              ({per_cat} questions/category) ###\n");

    let mut engine = engine_for(&artifacts, model, Method::Ctc)
        .expect("engine (run `make artifacts`)");

    let mut columns = Vec::new();
    let mut json = Vec::new();
    for method in [Method::Ctc, Method::Medusa, Method::Vanilla] {
        engine.set_method(method, true);
        let outcome = run_workload(&mut engine, &qs, max_new).unwrap();
        for (cat, s) in &outcome.per_category {
            json.push(ctcdraft::bench::result_from_summary(
                &format!("{}/{cat}", method.name()), s));
        }
        columns.push((method.name(), outcome.per_category));
    }
    if let Err(e) = ctcdraft::bench::write_json("fig2_categories", &json) {
        eprintln!("failed to write BENCH_fig2_categories.json: {e}");
    }

    let mut rows = Vec::new();
    for cat in CATEGORIES {
        let mut row = vec![cat.to_string()];
        for (_, per_cat_map) in &columns {
            let beta = per_cat_map.get(cat).map(|s| s.beta()).unwrap_or(0.0);
            row.push(format!("{beta:.2}"));
        }
        rows.push(row);
    }
    print!("{}", render_table(
        &["category", "ctc β", "medusa β", "vanilla β"], &rows));

    // simple ASCII bars for the ctc column (the figure itself)
    println!("\nctc-drafter β by category:");
    for cat in CATEGORIES {
        let beta = columns[0].1.get(cat).map(|s| s.beta()).unwrap_or(0.0);
        let bar = "█".repeat((beta * 8.0).round() as usize);
        println!("  {cat:11} {beta:4.2} {bar}");
    }
    println!("\npaper: coding highest (~4.0 ctc), roleplay lowest for ctc; \
              ctc > medusa in every category");
}
