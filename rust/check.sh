#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite.
#
# CTCD_PROP_FAST=1 scales the randomized property/simulation case counts
# down (testkit::Prop: 100 → 25 cases) so the gate stays fast; reproduce a
# specific property failure with CTCD_PROP_SEED=<seed> cargo test <name>.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
CTCD_PROP_FAST=1 cargo test -q
