#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite.
#
# CTCD_PROP_FAST=1 scales the randomized property/simulation case counts
# down (testkit::Prop: 100 → 25 cases) so the gate stays fast; reproduce a
# specific property failure with CTCD_PROP_SEED=<seed> cargo test <name>.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
# The full suite includes the SchedulerSim scenario suite
# (rust/tests/scheduler_sim.rs: interleaved chunked prefill,
# interactive-preempts-batch, deadline misses, head-blocking regression).
CTCD_PROP_FAST=1 cargo test -q

# Determinism audit: two replays of the same seeded class-tagged trace must
# produce byte-identical scheduler event logs. Any diff fails the gate.
for seed in 7 41; do
  a="$(./target/release/ctcdraft sim --seed "$seed")"
  b="$(./target/release/ctcdraft sim --seed "$seed")"
  if [ "$a" != "$b" ]; then
    echo "FAIL: SchedulerSim replay for seed $seed is nondeterministic" >&2
    diff <(printf '%s\n' "$a") <(printf '%s\n' "$b") >&2 || true
    exit 1
  fi
done
echo "scheduler-sim replay determinism: OK"
