#!/usr/bin/env bash
# Tier-1 gate: release build + full test suite.
#
# CTCD_PROP_FAST=1 scales the randomized property/simulation case counts
# down (testkit::Prop: 100 → 25 cases) so the gate stays fast; reproduce a
# specific property failure with CTCD_PROP_SEED=<seed> cargo test <name>.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
# The full suite includes the SchedulerSim scenario suite
# (rust/tests/scheduler_sim.rs: interleaved chunked prefill,
# interactive-preempts-batch, deadline misses, head-blocking regression,
# class-aware prefill ordering, adaptive-β replay), the zero-allocation
# hot-path gate (rust/tests/hotpath_alloc.rs), and the event-driven
# frontend concurrency suite (rust/tests/server_integration.rs): under
# CTCD_PROP_FAST=1 the C10k fan-in test runs as a 96-client smoke (500
# clients in the full run), plus the slow-reader shed test and the
# bounded-acceptor flood test — all against the artifact-free mock engine,
# so they gate every CI run.
CTCD_PROP_FAST=1 cargo test -q

# Determinism audit: two replays of the same seeded trace must produce
# byte-identical scheduler event logs — under BOTH β policies (fixed and
# batch-adaptive), for BOTH the single-worker mock and the two-workers-
# over-one-shared-pool cluster (placement + lease stealing on the replay
# path), and for BOTH workload shapes (poisson MT-bench arrivals and the
# prefix-chained multiturn conversations that exercise the prefix-sharing
# cache). Any diff fails the gate.
for seed in 7 41; do
  for beta in fixed adaptive; do
    for workers in 1 2; do
      for trace in poisson multiturn; do
        a="$(./target/release/ctcdraft sim --seed "$seed" --beta-policy "$beta" --workers "$workers" --trace "$trace")"
        b="$(./target/release/ctcdraft sim --seed "$seed" --beta-policy "$beta" --workers "$workers" --trace "$trace")"
        if [ "$a" != "$b" ]; then
          echo "FAIL: SchedulerSim replay (seed $seed, beta $beta, workers $workers, trace $trace) is nondeterministic" >&2
          diff <(printf '%s\n' "$a") <(printf '%s\n' "$b") >&2 || true
          exit 1
        fi
      done
    done
  done
done
# the cluster replay must actually route through the placement policy
if ! ./target/release/ctcdraft sim --seed 7 --workers 2 | grep -q " place id="; then
  echo "FAIL: cluster sim log records no placement decisions" >&2
  exit 1
fi
echo "scheduler-sim replay determinism (fixed + adaptive beta, 1 + 2 workers, poisson + multiturn): OK"

# Prefix-reuse gate: on the multiturn trace (every turn's prompt extends
# the previous one) the warm prefix-sharing run must record cache hits and
# saved prefill blocks, and must spend STRICTLY fewer prefill rounds than
# the cold baseline (--no-prefix-share) on the identical trace.
field() { printf '%s\n' "$1" | tr ' ' '\n' | sed -n "s/^$2=//p" | head -n1; }
warm="$(./target/release/ctcdraft sim --seed 7 --trace multiturn --summary 2>&1 >/dev/null)"
cold="$(./target/release/ctcdraft sim --seed 7 --trace multiturn --no-prefix-share --summary 2>&1 >/dev/null)"
warm_hits="$(field "$warm" prefix_hits)"
warm_saved="$(field "$warm" prefix_saved)"
warm_prefill="$(field "$warm" prefill_steps)"
cold_prefill="$(field "$cold" prefill_steps)"
if [ -z "$warm_hits" ] || [ "$warm_hits" -eq 0 ] || [ -z "$warm_saved" ] || [ "$warm_saved" -eq 0 ]; then
  echo "FAIL: multiturn warm run recorded no prefix reuse (hits=$warm_hits saved=$warm_saved)" >&2
  echo "warm summary: $warm" >&2
  exit 1
fi
if [ -z "$warm_prefill" ] || [ -z "$cold_prefill" ] || [ "$warm_prefill" -ge "$cold_prefill" ]; then
  echo "FAIL: prefix sharing did not cut prefill work (warm $warm_prefill vs cold $cold_prefill prefill steps)" >&2
  echo "warm summary: $warm" >&2
  echo "cold summary: $cold" >&2
  exit 1
fi
echo "prefix-reuse gate: OK (hits=$warm_hits saved=$warm_saved blocks, prefill $warm_prefill < $cold_prefill cold)"

# Bench smoke: the micro hot-path bench must run in --smoke mode and leave
# a well-formed machine-readable BENCH_micro_hotpath.json behind (the
# cross-PR perf-trajectory artifact).
rm -f BENCH_micro_hotpath.json
cargo bench --bench micro_hotpath -- --smoke >/dev/null
test -s BENCH_micro_hotpath.json || {
  echo "FAIL: BENCH_micro_hotpath.json missing or empty" >&2; exit 1;
}
python3 - <<'EOF'
import json, sys
with open("BENCH_micro_hotpath.json") as f:
    doc = json.load(f)
assert doc.get("bench") == "micro_hotpath", doc.get("bench")
results = doc["results"]
assert results, "no bench results recorded"
for r in results:
    for key in ("name", "iters", "mean_s", "p50_s", "p95_s"):
        assert key in r, f"missing {key} in {r}"
names = {r["name"] for r in results}
need = {"hotpath_round(legacy)", "hotpath_round(scratch)"}
missing = need - names
assert not missing, f"missing hot-round entries: {missing}"
print("BENCH_micro_hotpath.json: OK (%d entries)" % len(results))

# Perf ratchet (machine-readable, CI-enforced): the arena/scratch hot round
# must stay within 1.15x of the legacy (seed) implementation's mean in the
# smoke run. A regression past that fails the gate — the cross-PR perf
# trajectory is enforced, not just recorded.
by_name = {r["name"]: r for r in results}
legacy = by_name["hotpath_round(legacy)"]["mean_s"]
scratch = by_name["hotpath_round(scratch)"]["mean_s"]
assert legacy > 0, "legacy hot-round mean is zero — bench broken"
ratio = scratch / legacy
limit = 1.15
assert ratio <= limit, (
    f"PERF RATCHET FAIL: hotpath_round(scratch) mean {scratch:.3e}s is "
    f"{ratio:.2f}x legacy ({legacy:.3e}s); limit {limit}x")
print(f"perf ratchet: OK (scratch/legacy mean ratio {ratio:.2f} <= {limit})")
EOF
echo "bench smoke: OK"

# Shed-replay determinism: the seeded write-queue shed simulation must be
# byte-identical across two invocations (the shed path — enqueue order,
# stall schedule, shed decisions — carries no hidden nondeterminism), and
# the scenario must actually shed at least one connection so the gate
# exercises the condemn path rather than vacuously passing.
ra="$(./target/release/ctcdraft shedreplay --seed 7 --conns 24 --cap 8 --rounds 64)"
rb="$(./target/release/ctcdraft shedreplay --seed 7 --conns 24 --cap 8 --rounds 64)"
if [ "$ra" != "$rb" ]; then
  echo "FAIL: shed-replay is nondeterministic across identical seeded runs" >&2
  diff <(printf '%s\n' "$ra") <(printf '%s\n' "$rb") >&2 || true
  exit 1
fi
shed_count="$(printf '%s\n' "$ra" | sed -n 's/^total shed=\([0-9]*\).*/\1/p')"
if [ -z "$shed_count" ] || [ "$shed_count" -eq 0 ]; then
  echo "FAIL: shed-replay (seed 7, conns 24, cap 8) shed no connections — gate is vacuous" >&2
  printf '%s\n' "$ra" >&2
  exit 1
fi
echo "shed-replay determinism: OK ($shed_count sheds, byte-identical)"

# Connection fan-in bench smoke: connbench spins up the mock-engine server
# twice (4-client baseline, then the fan-in run) with identical slot counts
# and emits BENCH_conn_fanin.json — the per-connection frontend overhead
# artifact tracked across PRs.
rm -f BENCH_conn_fanin.json
./target/release/ctcdraft connbench --smoke >/dev/null
test -s BENCH_conn_fanin.json || {
  echo "FAIL: BENCH_conn_fanin.json missing or empty" >&2; exit 1;
}
python3 - <<'EOF'
import json
with open("BENCH_conn_fanin.json") as f:
    doc = json.load(f)
assert doc.get("bench") == "conn_fanin", doc.get("bench")
results = doc["results"]
names = {r["name"]: r for r in results}
need = [n for n in names if n.startswith("conn_round(base")]
assert need, f"missing baseline entry in {sorted(names)}"
fan = [n for n in names if n.startswith("conn_round(fanin")]
assert fan, f"missing fan-in entry in {sorted(names)}"
assert "fanin_per_conn_overhead" in names, sorted(names)
for r in results:
    for key in ("name", "iters", "mean_s", "p50_s", "p95_s"):
        assert key in r, f"missing {key} in {r}"

# Per-connection overhead ceiling: the marginal cost one extra multiplexed
# connection adds to a scheduler round. The driver's per-conn work is a
# readiness probe + queue pump (microseconds); 100µs/conn is ~50x the
# expected cost, generous enough for a loaded single-core CI box while
# still catching O(n) blow-ups (a thread-per-conn or quadratic-scan
# regression costs milliseconds per conn at smoke scale).
overhead = names["fanin_per_conn_overhead"]["mean_s"]
limit = 100e-6
assert overhead <= limit, (
    f"PER-CONN OVERHEAD FAIL: {overhead:.3e}s/conn exceeds {limit:.0e}s "
    f"ceiling — frontend no longer scales with connection count")
print(f"conn fan-in gate: OK (per-conn overhead {overhead*1e6:.2f}us <= {limit*1e6:.0f}us)")
EOF
echo "conn fan-in bench smoke: OK"

# Chaos gate: seeded fault injection (worker panics, step stalls, pool
# spikes, conn errors at exact virtual steps) must be (a) deterministic —
# two runs of the same fault plan print byte-identical event logs — and
# (b) survivable — at least one injected worker panic is followed by a
# supervisor recovery, with ZERO failed client streams — on both the
# single-worker and the two-worker cluster. Recovery latency and failover
# counts land in BENCH_chaos.json (the cross-PR resilience artifact).
rm -f BENCH_chaos.json chaos_w*.log chaos_w*.sum
for workers in 1 2; do
  ./target/release/ctcdraft sim --seed 7 --faults 11 --workers "$workers" \
    --summary >"chaos_w$workers.log" 2>"chaos_w$workers.sum"
  ./target/release/ctcdraft sim --seed 7 --faults 11 --workers "$workers" \
    >"chaos_w$workers.rerun" 2>/dev/null
  if ! cmp -s "chaos_w$workers.log" "chaos_w$workers.rerun"; then
    echo "FAIL: chaos replay (workers $workers) is nondeterministic" >&2
    diff "chaos_w$workers.log" "chaos_w$workers.rerun" >&2 || true
    exit 1
  fi
  sum="$(cat "chaos_w$workers.sum")"
  injected="$(field "$sum" faults_injected)"
  failed="$(field "$sum" failed_streams)"
  if ! grep -q "fault worker=.* kind=panic" "chaos_w$workers.log"; then
    echo "FAIL: chaos run (workers $workers) injected no worker panic" >&2
    exit 1
  fi
  if ! grep -q "recover worker=" "chaos_w$workers.log"; then
    echo "FAIL: chaos run (workers $workers) never recovered a crashed worker" >&2
    exit 1
  fi
  if [ -z "$injected" ] || [ "$injected" -lt 2 ]; then
    echo "FAIL: chaos run (workers $workers) applied $injected faults (< 2)" >&2
    echo "summary: $sum" >&2
    exit 1
  fi
  if [ -z "$failed" ] || [ "$failed" -ne 0 ]; then
    echo "FAIL: chaos run (workers $workers) failed $failed client streams" >&2
    echo "summary: $sum" >&2
    exit 1
  fi
done
python3 - <<'EOF'
import json, re

results = []
for workers in (1, 2):
    with open(f"chaos_w{workers}.sum") as f:
        sum_line = f.read().split()
    fields = dict(kv.split("=", 1) for kv in sum_line if "=" in kv)
    # pair each panic/watchdog fault with its worker's next recover event
    # to measure supervisor recovery latency in virtual steps
    down = {}
    latencies = []
    with open(f"chaos_w{workers}.log") as f:
        for line in f:
            m = re.match(r"t=(\d+) fault worker=(\d+) kind=(panic|watchdog)", line)
            if m:
                down.setdefault(int(m.group(2)), int(m.group(1)))
            m = re.match(r"t=(\d+) recover worker=(\d+)", line)
            if m and int(m.group(2)) in down:
                latencies.append(int(m.group(1)) - down.pop(int(m.group(2))))
    assert latencies, f"workers={workers}: no crash/recover pair in the log"
    # `down` may be non-empty: a crash on an already-idle worker near the
    # end of the run leaves nothing to drain, so the sim stops before the
    # restart backoff expires — benign (no stream depended on it)
    results.append({
        "name": f"chaos(workers={workers})",
        "faults_injected": int(fields["faults_injected"]),
        "failovers": int(fields["failovers"]),
        "failed_streams": int(fields["failed_streams"]),
        "recoveries": len(latencies),
        "recovery_latency_steps_mean": sum(latencies) / len(latencies),
        "recovery_latency_steps_max": max(latencies),
    })
with open("BENCH_chaos.json", "w") as f:
    json.dump({"bench": "chaos", "results": results}, f, indent=1)
for r in results:
    print("chaos gate: OK (%s: %d faults, %d failovers, %d recoveries, "
          "mean recovery %.1f steps, 0 failed streams)"
          % (r["name"], r["faults_injected"], r["failovers"],
             r["recoveries"], r["recovery_latency_steps_mean"]))
EOF
rm -f chaos_w*.log chaos_w*.sum chaos_w*.rerun
test -s BENCH_chaos.json || {
  echo "FAIL: BENCH_chaos.json missing or empty" >&2; exit 1;
}
echo "chaos gate: OK"

# Flaky-client shed replay: mid-stream disconnect-and-retry clients
# (the client half of request failover) must stay byte-deterministic and
# must actually exercise the drop-and-replay path.
fa="$(./target/release/ctcdraft shedreplay --seed 7 --conns 24 --cap 8 --rounds 64 --flaky-frac 0.25)"
fb="$(./target/release/ctcdraft shedreplay --seed 7 --conns 24 --cap 8 --rounds 64 --flaky-frac 0.25)"
if [ "$fa" != "$fb" ]; then
  echo "FAIL: flaky shed-replay is nondeterministic" >&2
  diff <(printf '%s\n' "$fa") <(printf '%s\n' "$fb") >&2 || true
  exit 1
fi
flaky_retries="$(printf '%s\n' "$fa" | sed -n 's/.*flaky_retries=\([0-9]*\).*/\1/p')"
if [ -z "$flaky_retries" ] || [ "$flaky_retries" -eq 0 ]; then
  echo "FAIL: flaky shed-replay recorded no reconnect-and-retry clients" >&2
  exit 1
fi
echo "flaky shed-replay determinism: OK ($flaky_retries reconnect-and-retries, byte-identical)"

# Multi-tenant scenario gates: every library scenario must double-replay
# byte-identically on BOTH the single-worker mock and the two-worker
# cluster — tenant interning, token-bucket refill, WFQ ordering, and the
# per-tenant degradation ladders all run on the virtual-step clock, so any
# hidden nondeterminism in the tenant layer diffs here.
for sc in diurnal agentic longctx noisy_neighbor cancel_storm; do
  for workers in 1 2; do
    sa="$(./target/release/ctcdraft sim --seed 7 --workers "$workers" --scenario "$sc")"
    sb="$(./target/release/ctcdraft sim --seed 7 --workers "$workers" --scenario "$sc")"
    if [ "$sa" != "$sb" ]; then
      echo "FAIL: scenario $sc (workers $workers) replay is nondeterministic" >&2
      diff <(printf '%s\n' "$sa") <(printf '%s\n' "$sb") >&2 || true
      exit 1
    fi
  done
done
echo "scenario replay determinism (5 scenarios, 1 + 2 workers): OK"

# Isolation gate: in noisy_neighbor the flooding batch tenant must be
# throttled by its OWN token bucket (busy > 0) and degraded by its OWN
# ladder (tenant-scoped, before the cluster ladder) while the interactive
# victim keeps admitting (never paused) and a bounded deadline-miss rate.
# This is the co-tenant blast-radius contract the PR exists for.
./target/release/ctcdraft sim --seed 7 --scenario noisy_neighbor \
  --summary >nn.log 2>nn.sum
if ! grep -q "tenant-degrade name=noisy" nn.log; then
  echo "FAIL: noisy_neighbor never tenant-degraded the flooding tenant" >&2
  exit 1
fi
# a transient no-spec tick on the victim during an all-victim pool pileup
# is tolerated; cutting off victim ADMISSION is not
if grep "tenant-degrade name=tenant-a" nn.log | grep -q "rung=admit-pause"; then
  echo "FAIL: noisy_neighbor admit-paused the VICTIM tenant — isolation leaked" >&2
  grep "tenant-degrade" nn.log >&2
  exit 1
fi
victim_line="$(grep '^tenant=tenant-a ' nn.sum || true)"
noisy_line="$(grep '^tenant=noisy ' nn.sum || true)"
if [ -z "$victim_line" ] || [ -z "$noisy_line" ]; then
  echo "FAIL: noisy_neighbor summary is missing per-tenant rollup lines" >&2
  cat nn.sum >&2
  exit 1
fi
noisy_busy="$(field "$noisy_line" busy)"
if [ -z "$noisy_busy" ] || [ "$noisy_busy" -eq 0 ]; then
  echo "FAIL: flooding tenant was never bounced (busy=0) — bucket is vacuous" >&2
  echo "$noisy_line" >&2
  exit 1
fi
victim_finished="$(field "$victim_line" finished)"
if [ -z "$victim_finished" ] || [ "$victim_finished" -eq 0 ]; then
  echo "FAIL: victim tenant finished nothing under the flood" >&2
  echo "$victim_line" >&2
  exit 1
fi
victim_miss="$(field "$victim_line" miss_rate)"
if ! awk -v m="$victim_miss" 'BEGIN { exit !(m <= 0.25) }'; then
  echo "FAIL: victim miss rate $victim_miss > 0.25 under the noisy flood" >&2
  echo "$victim_line" >&2
  exit 1
fi
rm -f nn.log nn.sum
echo "noisy-neighbor isolation gate: OK (victim miss_rate=$victim_miss, noisy bounced $noisy_busy times, degradation scoped to offender)"

# Scenario bench smoke: scenbench replays the whole library and leaves a
# well-formed BENCH_scenarios.json behind (the cross-PR multi-tenant QoS
# artifact: per-scenario throughput/miss/TTFT plus per-tenant rollups).
rm -f BENCH_scenarios.json
./target/release/ctcdraft scenbench --smoke >/dev/null 2>&1
test -s BENCH_scenarios.json || {
  echo "FAIL: BENCH_scenarios.json missing or empty" >&2; exit 1;
}
python3 - <<'EOF2'
import json
with open("BENCH_scenarios.json") as f:
    doc = json.load(f)
assert doc.get("bench") == "scenarios", doc.get("bench")
results = doc["results"]
names = [r["name"] for r in results]
need = ["diurnal", "agentic", "longctx", "noisy_neighbor", "cancel_storm"]
assert names == need, f"scenario set drifted: {names}"
for r in results:
    for key in ("steps", "finished", "deadline_misses", "miss_rate",
                "ttft_mean_steps", "throughput_tokens_per_step"):
        assert key in r, f"{r['name']}: missing {key}"
    assert r["finished"] > 0, f"{r['name']}: nothing finished"
    assert 0.0 <= r["miss_rate"] <= 1.0, (r["name"], r["miss_rate"])
    tenants = r["tenants"]
    assert tenants, f"{r['name']}: no per-tenant rollups"
    for tname, t in tenants.items():
        assert t["submitted"] > 0, f"{r['name']}/{tname}: submitted=0"
        assert t["finished"] + t["busy"] <= t["submitted"], (
            f"{r['name']}/{tname}: finished+busy exceeds submitted")
print("BENCH_scenarios.json: OK (%d scenarios, per-tenant rollups present)"
      % len(results))
EOF2
echo "scenario bench smoke: OK"

# Speculation-policy gate: with the drafter portfolio under
# --spec-policy auto, the mixed spec trace (copy-heavy + chat +
# rejection-heavy tenants) must (a) double-replay byte-identically on
# both the single-worker mock and the cluster, (b) record online
# drafter switches in the canonical log, and (c) demonstrably demote a
# rejection-heavy sequence all the way to no-speculation (a
# drafter-switch event landing on to=none). Default-config runs must
# stay policy-silent: no drafter-switch events, byte-compatible with
# the pre-portfolio log shape.
for workers in 1 2; do
  a="$(./target/release/ctcdraft sim --seed 7 --workers "$workers" --trace spec_mixed --spec-policy auto --drafter-portfolio ctc,lookup,none)"
  b="$(./target/release/ctcdraft sim --seed 7 --workers "$workers" --trace spec_mixed --spec-policy auto --drafter-portfolio ctc,lookup,none)"
  if [ "$a" != "$b" ]; then
    echo "FAIL: --spec-policy auto replay (workers $workers) is nondeterministic" >&2
    diff <(printf '%s\n' "$a") <(printf '%s\n' "$b") >&2 || true
    exit 1
  fi
  if ! printf '%s\n' "$a" | grep -q " drafter-switch id="; then
    echo "FAIL: auto policy run (workers $workers) recorded no drafter switches" >&2
    exit 1
  fi
  if ! printf '%s\n' "$a" | grep -q " drafter-switch id=.* to=none"; then
    echo "FAIL: rejection-heavy tenant never demoted to no-speculation (workers $workers)" >&2
    printf '%s\n' "$a" | grep " drafter-switch id=" >&2 || true
    exit 1
  fi
done
if ./target/release/ctcdraft sim --seed 7 --trace spec_mixed | grep -q "drafter-switch"; then
  echo "FAIL: default (fixed-policy) run emitted drafter-switch events" >&2
  exit 1
fi
echo "spec-policy auto gate: OK (replays byte-identical on 1 + 2 workers, rejection-heavy demotes to none, defaults policy-silent)"

# Portfolio bench: specbench runs spec_mixed once under the auto policy
# and once pinned to each portfolio member, and leaves a well-formed
# BENCH_portfolio.json behind. The portfolio-wins invariant — auto
# matches or beats every single-drafter run on accepted-tokens/step —
# is the gate on the online selector actually earning its keep.
rm -f BENCH_portfolio.json
./target/release/ctcdraft specbench --smoke >/dev/null 2>&1
test -s BENCH_portfolio.json || {
  echo "FAIL: BENCH_portfolio.json missing or empty" >&2; exit 1;
}
python3 - <<'EOF3'
import json
with open("BENCH_portfolio.json") as f:
    doc = json.load(f)
assert doc.get("bench") == "portfolio", doc.get("bench")
assert doc.get("trace") == "spec_mixed", doc.get("trace")
assert doc.get("portfolio"), "empty portfolio"
results = doc["results"]
assert results and results[0]["name"] == "portfolio(auto)", \
    [r["name"] for r in results]
singles = [r for r in results[1:]]
assert singles, "no single-drafter baselines"
assert [r["name"] for r in singles] == \
    ["single(%s)" % k for k in doc["portfolio"]], \
    [r["name"] for r in singles]
for r in results:
    for key in ("name", "mode", "kinds", "steps", "finished", "tokens",
                "accepted_tokens_per_step", "switches"):
        assert key in r, f"{r.get('name')}: missing {key}"
    assert r["finished"] > 0, f"{r['name']}: nothing finished"
    assert r["steps"] > 0, f"{r['name']}: zero steps"
auto = results[0]
assert auto["mode"] == "auto", auto["mode"]
assert auto["switches"] > 0, "auto policy never switched drafters"
for r in singles:
    assert r["mode"] == "fixed", (r["name"], r["mode"])
    assert r["switches"] == 0, (r["name"], r["switches"])
best = max(r["accepted_tokens_per_step"] for r in singles)
assert auto["accepted_tokens_per_step"] >= best - 1e-9, (
    "portfolio loses to a single drafter: auto=%.3f best_single=%.3f"
    % (auto["accepted_tokens_per_step"], best))
print("BENCH_portfolio.json: OK (auto %.3f acc-tok/step >= best single "
      "%.3f, %d switches)"
      % (auto["accepted_tokens_per_step"], best, auto["switches"]))
EOF3
echo "portfolio bench gate: OK"
