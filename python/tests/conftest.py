import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# keep test-time training fast
os.environ.setdefault("CTCD_STEPS_BASE", "6")
os.environ.setdefault("CTCD_STEPS_HEAD", "4")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(scope="session")
def tiny_cfg():
    """A scaled-down config so model tests run in seconds."""
    return dict(family="vic", analog="test", layers=2, d_model=64,
                n_heads=2, d_ff=128, act="swiglu")


@pytest.fixture(scope="session")
def gelu_cfg():
    return dict(family="lc2", analog="test", layers=2, d_model=64,
                n_heads=2, d_ff=128, act="gelu")


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    import jax

    from compile import model as M
    return M.init_params(tiny_cfg, jax.random.PRNGKey(7))
