"""Byte-BPE tokenizer: roundtrips, determinism, vocab invariants."""

import json

import pytest
pytest.importorskip("hypothesis", reason="hypothesis unavailable in the offline test image")
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import constants as C
from compile.tokenizer import ByteBpe, train_bpe

CORPUS = (
    "the quick brown fox jumps over the lazy dog. "
    "the quick brown fox returns. pack my box with five dozen jugs. "
    "def add(a, b):\n    return a + b\n" * 20
)


@pytest.fixture(scope="module")
def bpe():
    return train_bpe(CORPUS, n_merges=80)


def test_vocab_layout(bpe):
    # specials + bytes + merges, merges capped
    assert bpe.vocab_size <= C.VOCAB_SIZE
    assert bpe.token_bytes[C.N_SPECIAL] == b"\x00"
    assert bpe.token_bytes[C.N_SPECIAL + 65] == b"A"
    for i, (a, b) in enumerate(bpe.merges):
        tid = C.N_SPECIAL + C.N_BYTES + i
        assert bpe.token_bytes[tid] == bpe.token_bytes[a] + bpe.token_bytes[b]


def test_roundtrip_corpus(bpe):
    assert bpe.decode(bpe.encode(CORPUS)) == CORPUS


def test_merges_actually_used(bpe):
    ids = bpe.encode("the quick brown fox")
    assert any(i >= C.N_SPECIAL + C.N_BYTES for i in ids), \
        "expected at least one merged token on in-distribution text"


def test_bos_eos(bpe):
    ids = bpe.encode("hi", bos=True, eos=True)
    assert ids[0] == C.BOS_ID and ids[-1] == C.EOS_ID


def test_empty(bpe):
    assert bpe.encode("") == []
    assert bpe.decode([]) == ""


def test_determinism():
    a = train_bpe(CORPUS, n_merges=40)
    b = train_bpe(CORPUS, n_merges=40)
    assert a.merges == b.merges


def test_save_load_roundtrip(bpe, tmp_path):
    path = tmp_path / "vocab.json"
    bpe.save(str(path))
    loaded = ByteBpe.load(str(path))
    assert loaded.merges == bpe.merges
    assert loaded.encode(CORPUS) == bpe.encode(CORPUS)
    # the json also carries explicit token bytes for the rust decoder
    data = json.load(open(path))
    assert data["token_bytes"][C.N_SPECIAL + 97] == [97]  # 'a'


@settings(max_examples=60, deadline=None)
@given(st.text(max_size=200))
def test_roundtrip_any_text(text):
    bpe = _CACHED
    assert bpe.decode(bpe.encode(text)) == text


@settings(max_examples=40, deadline=None)
@given(st.binary(max_size=100))
def test_roundtrip_any_bytes_via_latin(data):
    # arbitrary byte content via utf-8 decodable wrapper
    text = data.decode("utf-8", errors="replace")
    bpe = _CACHED
    assert bpe.decode(bpe.encode(text)) == text


_CACHED = train_bpe(CORPUS, n_merges=80)
