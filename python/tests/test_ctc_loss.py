"""CTC loss: Pallas kernel vs jnp reference vs brute-force enumeration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis unavailable in the offline test image")
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import constants as C
from compile.kernels.ctc_loss import ctc_neg_logp
from compile.kernels.ref import (ctc_brute_force_neg_logp,
                                 ctc_extend_targets,
                                 ctc_neg_logp_batch_ref, ctc_neg_logp_ref)


def _rand_logp(rng, b, t, v):
    logits = rng.normal(size=(b, t, v)).astype(np.float32)
    return jax.nn.log_softmax(jnp.asarray(logits), -1)


def test_extend_targets():
    ext = ctc_extend_targets(jnp.array([[4, 7, 4]]), 9)
    assert ext.tolist() == [[9, 4, 9, 7, 9, 4, 9]]


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(min_value=2, max_value=10),
    u=st.integers(min_value=1, max_value=6),
    v=st.integers(min_value=2, max_value=20),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_kernel_matches_ref(t, u, v, seed):
    rng = np.random.default_rng(seed)
    b = 3
    logp = _rand_logp(rng, b, t, v + 1)
    targets = jnp.asarray(rng.integers(0, v, size=(b, u)), jnp.int32)
    tgt_len = jnp.asarray(rng.integers(0, u + 1, size=(b,)), jnp.int32)
    nll_k = np.asarray(ctc_neg_logp(logp, targets, tgt_len, v))
    nll_r = np.asarray(ctc_neg_logp_batch_ref(logp, targets, tgt_len, v))
    # impossible targets produce a huge sentinel whose exact magnitude
    # depends on how many -1e9 terms accumulate; clamp before comparing
    np.testing.assert_allclose(np.minimum(nll_k, 1e8), np.minimum(nll_r, 1e8),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    t=st.integers(min_value=1, max_value=5),
    v=st.integers(min_value=1, max_value=3),
    ulen=st.integers(min_value=0, max_value=3),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_dp_matches_brute_force(t, v, ulen, seed):
    rng = np.random.default_rng(seed)
    ulen = min(ulen, t)  # longer targets than slots are impossible anyway
    logp = _rand_logp(rng, 1, t, v + 1)
    tgt = rng.integers(0, v, size=(3,)).astype(np.int32)
    # forbid adjacent repeats? no — CTC handles them; keep raw randomness
    bf = ctc_brute_force_neg_logp(np.asarray(logp[0]), list(tgt[:ulen]), v)
    dp = ctc_neg_logp_ref(logp[0], jnp.asarray(tgt), jnp.int32(ulen), v)
    if np.isinf(bf):
        assert float(dp) > 1e8  # both say "impossible"
    else:
        np.testing.assert_allclose(float(dp), bf, rtol=1e-4, atol=1e-4)


def test_empty_target_prob_is_all_blanks():
    # P(empty) = prod_t p(blank); nll = -sum log p(blank)
    rng = np.random.default_rng(0)
    logp = _rand_logp(rng, 1, 5, 4)
    nll = ctc_neg_logp_ref(logp[0], jnp.zeros((3,), jnp.int32), jnp.int32(0), 3)
    expect = -float(jnp.sum(logp[0, :, 3]))
    np.testing.assert_allclose(float(nll), expect, rtol=1e-5)


def test_impossible_target_longer_than_slots():
    rng = np.random.default_rng(1)
    logp = _rand_logp(rng, 1, 2, 4)  # T=2 alignment slots
    # 3 distinct tokens cannot fit in 2 alignment slots
    nll = ctc_neg_logp_ref(logp[0], jnp.array([0, 1, 2]), jnp.int32(3), 3)
    assert float(nll) > 1e8


def test_repeat_needs_separating_blank():
    # target [a, a] in 2 slots is impossible (needs a blank between)
    rng = np.random.default_rng(2)
    logp = _rand_logp(rng, 2, 2, 3)
    nll = ctc_neg_logp_ref(logp[0], jnp.array([1, 1]), jnp.int32(2), 2)
    assert float(nll) > 1e8
    # ...but in 3 slots it is possible
    logp3 = _rand_logp(rng, 1, 3, 3)
    nll3 = ctc_neg_logp_ref(logp3[0], jnp.array([1, 1, 0]), jnp.int32(2), 2)
    assert float(nll3) < 1e8


def test_nll_nonnegative_property():
    rng = np.random.default_rng(3)
    for seed in range(5):
        logp = _rand_logp(rng, 2, C.DRAFT_SLOTS, C.DRAFT_VOCAB)
        targets = jnp.asarray(
            rng.integers(0, C.VOCAB_SIZE, size=(2, C.CTC_TARGET_U)), jnp.int32)
        tgt_len = jnp.asarray([1, C.CTC_TARGET_U], jnp.int32)
        nll = ctc_neg_logp(logp, targets, tgt_len, C.BLANK_ID)
        assert np.all(np.asarray(nll) >= -1e-4)


def test_gradients_flow_through_ref():
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(1, 6, 8)), jnp.float32)

    def loss(lg):
        lp = jax.nn.log_softmax(lg, -1)
        return jnp.sum(ctc_neg_logp_batch_ref(
            lp, jnp.array([[1, 2, 3]]), jnp.array([3]), 7))

    g = jax.grad(loss)(logits)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.max(jnp.abs(g))) > 0
