"""Base model: step/full-forward consistency, tree verify semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import constants as C
from compile import model as M
from compile.kernels.ref import NEG_INF


def _zero_cache(cfg, b):
    shp = (cfg["layers"], b, C.LMAX, cfg["n_heads"], C.HEAD_DIM)
    return jnp.zeros(shp), jnp.zeros(shp)


def _decode_bias(t, n=1):
    """bias for decoding one token at absolute position t."""
    bias = np.full((1, n, C.LMAX + n), NEG_INF, np.float32)
    bias[0, :, :t] = 0.0
    for i in range(n):
        bias[0, i, C.LMAX: C.LMAX + i + 1] = 0.0
    return jnp.asarray(bias)


@pytest.fixture(scope="module")
def toks(rng):
    return rng.integers(3, C.VOCAB_SIZE, size=(1, 12)).astype(np.int32)


class TestStepConsistency:
    def test_stepwise_decode_matches_full_forward(self, tiny_cfg, tiny_params, toks):
        logits_full, hidden_full = M.lm_forward(
            tiny_params, tiny_cfg, jnp.asarray(toks))
        kc, vc = _zero_cache(tiny_cfg, 1)
        for t in range(toks.shape[1]):
            lg, kn, vn, hd = M.step_forward(
                tiny_params, tiny_cfg, kc, vc,
                jnp.asarray(toks[:, t:t + 1]),
                jnp.full((1, 1), t, jnp.int32), _decode_bias(t))
            kc = kc.at[:, :, t].set(kn[:, :, 0])
            vc = vc.at[:, :, t].set(vn[:, :, 0])
            np.testing.assert_allclose(
                np.asarray(lg[0, 0]), np.asarray(logits_full[0, t]),
                rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(
                np.asarray(hd[0, 0]), np.asarray(hidden_full[0, t]),
                rtol=1e-4, atol=1e-4)

    def test_chunked_prefill_matches_full_forward(self, tiny_cfg, tiny_params, toks):
        n = toks.shape[1]
        logits_full, _ = M.lm_forward(tiny_params, tiny_cfg, jnp.asarray(toks))
        kc, vc = _zero_cache(tiny_cfg, 1)
        # one chunk of n tokens with a causal bias
        bias = np.full((1, n, C.LMAX + n), NEG_INF, np.float32)
        for i in range(n):
            bias[0, i, C.LMAX: C.LMAX + i + 1] = 0.0
        lg, kn, vn, hd = M.step_forward(
            tiny_params, tiny_cfg, kc, vc, jnp.asarray(toks),
            jnp.arange(n, dtype=jnp.int32)[None], jnp.asarray(bias))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_full),
                                   rtol=1e-4, atol=1e-4)

    def test_gelu_family_also_consistent(self, gelu_cfg, toks):
        params = M.init_params(gelu_cfg, jax.random.PRNGKey(3))
        logits_full, _ = M.lm_forward(params, gelu_cfg, jnp.asarray(toks))
        kc, vc = _zero_cache(gelu_cfg, 1)
        lg, *_ = M.step_forward(
            params, gelu_cfg, kc, vc, jnp.asarray(toks[:, :1]),
            jnp.zeros((1, 1), jnp.int32), _decode_bias(0))
        np.testing.assert_allclose(np.asarray(lg[0, 0]),
                                   np.asarray(logits_full[0, 0]),
                                   rtol=1e-4, atol=1e-4)


class TestTreeVerify:
    def test_linear_chain_tree_equals_sequential_decode(
            self, tiny_cfg, tiny_params, toks):
        """A degenerate tree (single path) must reproduce AR decoding."""
        prefix_len, chain = 4, 5
        # prefill the prefix token-by-token
        kc, vc = _zero_cache(tiny_cfg, 1)
        for t in range(prefix_len):
            _, kn, vn, _ = M.step_forward(
                tiny_params, tiny_cfg, kc, vc, jnp.asarray(toks[:, t:t + 1]),
                jnp.full((1, 1), t, jnp.int32), _decode_bias(t))
            kc = kc.at[:, :, t].set(kn[:, :, 0])
            vc = vc.at[:, :, t].set(vn[:, :, 0])

        chain_toks = toks[:, prefix_len:prefix_len + chain]
        # tree bias: node i sees cache[0:prefix_len] + nodes 0..i
        n = chain
        bias = np.full((1, n, C.LMAX + n), NEG_INF, np.float32)
        bias[0, :, :prefix_len] = 0.0
        for i in range(n):
            bias[0, i, C.LMAX: C.LMAX + i + 1] = 0.0
        pos = (prefix_len + np.arange(n, dtype=np.int32))[None]
        tree_lg, *_ = M.step_forward(
            tiny_params, tiny_cfg, kc, vc, jnp.asarray(chain_toks),
            jnp.asarray(pos), jnp.asarray(bias))

        # sequential decode of the same tokens
        kc2, vc2 = kc, vc
        seq_lg = []
        for i in range(chain):
            t = prefix_len + i
            lg, kn, vn, _ = M.step_forward(
                tiny_params, tiny_cfg, kc2, vc2,
                jnp.asarray(chain_toks[:, i:i + 1]),
                jnp.full((1, 1), t, jnp.int32), _decode_bias(t))
            kc2 = kc2.at[:, :, t].set(kn[:, :, 0])
            vc2 = vc2.at[:, :, t].set(vn[:, :, 0])
            seq_lg.append(np.asarray(lg[0, 0]))
        np.testing.assert_allclose(np.asarray(tree_lg[0]), np.stack(seq_lg),
                                   rtol=1e-4, atol=1e-4)

    def test_sibling_isolation(self, tiny_cfg, tiny_params, toks):
        """Two sibling branches must not attend to each other."""
        prefix_len = 3
        kc, vc = _zero_cache(tiny_cfg, 1)
        for t in range(prefix_len):
            _, kn, vn, _ = M.step_forward(
                tiny_params, tiny_cfg, kc, vc, jnp.asarray(toks[:, t:t + 1]),
                jnp.full((1, 1), t, jnp.int32), _decode_bias(t))
            kc = kc.at[:, :, t].set(kn[:, :, 0])
            vc = vc.at[:, :, t].set(vn[:, :, 0])

        # tree with two siblings a, b at the same depth
        a_tok, b_tok = 17, 23
        for variant_b in (b_tok, 101):  # changing sibling b ...
            tree = np.asarray([[a_tok, variant_b]], np.int32)
            bias = np.full((1, 2, C.LMAX + 2), NEG_INF, np.float32)
            bias[0, :, :prefix_len] = 0.0
            bias[0, 0, C.LMAX + 0] = 0.0
            bias[0, 1, C.LMAX + 1] = 0.0
            pos = np.asarray([[prefix_len, prefix_len]], np.int32)
            lg, *_ = M.step_forward(
                tiny_cfg and tiny_params, tiny_cfg, kc, vc, jnp.asarray(tree),
                jnp.asarray(pos), jnp.asarray(bias))
            if variant_b == b_tok:
                base_a = np.asarray(lg[0, 0])
            else:
                # ... must not change sibling a's logits
                np.testing.assert_allclose(np.asarray(lg[0, 0]), base_a,
                                           rtol=1e-5, atol=1e-5)


class TestParams:
    def test_weight_names_cover_params(self, tiny_cfg, tiny_params):
        assert set(M.weight_names(tiny_cfg)) == set(tiny_params.keys())

    def test_param_shapes_match(self, tiny_cfg, tiny_params):
        shapes = M.param_shapes(tiny_cfg)
        for k, v in tiny_params.items():
            assert tuple(v.shape) == shapes[k], k

    def test_gelu_has_no_gate(self, gelu_cfg):
        names = M.weight_names(gelu_cfg)
        assert not any("w_gate" in n for n in names)

    def test_flat_params_order(self, tiny_cfg, tiny_params):
        flat = M.flat_params(tiny_params, tiny_cfg)
        names = M.weight_names(tiny_cfg)
        assert len(flat) == len(names)
        assert flat[0] is tiny_params["emb"]
