"""Pallas tree-attention kernel vs the jnp oracle (hypothesis sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis unavailable in the offline test image")
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import NEG_INF, attention_ref
from compile.kernels.tree_attention import tree_attention, vmem_report


def _run_case(b, n, h, dh, m, mask_frac, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, n, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    bias = np.where(rng.random((b, n, m)) < mask_frac, NEG_INF, 0.0)
    bias = jnp.asarray(bias, jnp.float32)
    out = tree_attention(q, k, v, bias)
    ref = attention_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    n=st.sampled_from([1, 8, 32, 64]),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([16, 32]),
    m=st.integers(min_value=1, max_value=130),
    mask_frac=st.sampled_from([0.0, 0.3, 0.9]),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_matches_ref_random(b, n, h, dh, m, mask_frac, seed):
    _run_case(b, n, h, dh, m, mask_frac, seed)


def test_serving_shapes():
    # the exact shapes the exported step graphs use
    for n in (1, 32, 64):
        _run_case(1, n, 4, 32, 384 + n, 0.5, 99)


def test_fully_masked_rows_are_zero():
    b, n, h, dh, m = 1, 4, 2, 16, 8
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, n, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    bias = jnp.full((b, n, m), NEG_INF, jnp.float32)
    out = tree_attention(q, k, v, bias)
    assert np.allclose(np.asarray(out), 0.0)


def test_single_visible_key_returns_its_value():
    b, n, h, dh, m = 1, 2, 1, 16, 6
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, n, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    bias = np.full((b, n, m), NEG_INF, np.float32)
    bias[:, :, 3] = 0.0
    out = tree_attention(q, k, v, jnp.asarray(bias))
    expect = np.broadcast_to(np.asarray(v)[:, 3][:, None], out.shape)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)


def test_bias_shift_invariance():
    # adding a constant to a full bias row must not change the output
    b, n, h, dh, m = 1, 4, 2, 16, 20
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(b, n, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, m, h, dh)), jnp.float32)
    bias = jnp.asarray(rng.normal(size=(b, n, m)) * 2, jnp.float32)
    out1 = tree_attention(q, k, v, bias)
    out2 = tree_attention(q, k, v, bias + 3.5)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-5)


def test_vmem_report_reasonable():
    rep = vmem_report(n=32, m=416, dh=32)
    # must comfortably fit a TPU core's ~16 MiB VMEM
    assert rep["vmem_bytes"] < 1 << 20
    assert 0 < rep["mxu_tile_cover"] <= 1
    assert rep["grid_steps_per_bh"] == 7
