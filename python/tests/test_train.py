"""Training machinery: optimizer, distill targets, loss plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import constants as C
from compile import train as T
from compile import model as M


class TestAdamW:
    def test_descends_quadratic(self):
        params = {"x": jnp.array([5.0, -3.0])}
        opt = T.adamw_init(params)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}
            params, opt = T.adamw_update(params, grads, opt, lr=0.05, wd=0.0)
        assert float(jnp.max(jnp.abs(params["x"]))) < 0.1

    def test_grad_clip_bounds_update(self):
        params = {"x": jnp.zeros(4)}
        opt = T.adamw_init(params)
        huge = {"x": jnp.full(4, 1e9)}
        p2, _ = T.adamw_update(params, huge, opt, lr=0.1, wd=0.0)
        # clipped: first-step update magnitude == lr regardless of grad size
        assert float(jnp.max(jnp.abs(p2["x"]))) <= 0.1 + 1e-6

    def test_global_norm(self):
        tree = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
        assert abs(float(T.global_norm(tree)) - 5.0) < 1e-6

    def test_cosine_schedule_shape(self):
        lrs = [float(T.cosine_lr(s, 100, 1.0)) for s in range(100)]
        assert lrs[0] < lrs[19]            # warmup rises
        assert lrs[25] > lrs[99]           # then decays
        assert lrs[99] >= 0.0


class TestBatcher:
    def test_shapes_and_determinism(self):
        toks = np.arange(4000, dtype=np.int32)
        b1 = T.Batcher(toks, 4, 16, seed=9)
        b2 = T.Batcher(toks, 4, 16, seed=9)
        x1, x2 = b1.next(), b2.next()
        assert x1.shape == (4, 17)
        np.testing.assert_array_equal(x1, x2)

    def test_windows_are_contiguous(self):
        toks = np.arange(4000, dtype=np.int32)
        b = T.Batcher(toks, 2, 8, seed=1)
        x = b.next()
        for row in x:
            np.testing.assert_array_equal(np.diff(row), 1)


class TestDistillTargets:
    def test_hidden_windows_alignment(self):
        b, t, d = 1, 5, 3
        hidden = jnp.arange(b * t * d, dtype=jnp.float32).reshape(b, t, d)
        wins = T.hidden_windows(hidden)
        assert wins.shape == (b, t, C.HIDDEN_WIN, d)
        # newest element of window t is hidden[t]
        np.testing.assert_allclose(np.asarray(wins[0, 3, -1]),
                                   np.asarray(hidden[0, 3]))
        # one before that is hidden[t-1]
        np.testing.assert_allclose(np.asarray(wins[0, 3, -2]),
                                   np.asarray(hidden[0, 2]))
        # pre-sequence rows are zero
        np.testing.assert_allclose(np.asarray(wins[0, 0, :-1]), 0.0)

    def test_next_token_targets(self):
        labels = jnp.asarray([[10, 11, 12, 13]], jnp.int32)
        tgt, tlen = T.next_token_targets(labels, u=3)
        assert tgt.shape == (1, 4, 3)
        # targets start AFTER the base token: position t targets labels[t+1:]
        np.testing.assert_array_equal(np.asarray(tgt[0, 0]), [11, 12, 13])
        np.testing.assert_array_equal(np.asarray(tgt[0, 2]), [13, C.PAD_ID, C.PAD_ID])
        np.testing.assert_array_equal(np.asarray(tlen[0]), [3, 2, 1, 0])


class TestEndToEndSmoke:
    @pytest.fixture(scope="class")
    def corpus_tokens(self):
        # structured, learnable stream: short repeating pattern
        pattern = np.asarray([7, 8, 9, 10, 11, 12] * 800, np.int32)
        return pattern

    def test_base_learns_repeating_pattern(self, tiny_cfg, corpus_tokens):
        # 60 steps: the cosine schedule spends the first 20 in warmup
        params, losses = T.train_base(tiny_cfg, corpus_tokens, steps=60,
                                      log=lambda m: None)
        assert losses[-1] < losses[0] * 0.75, (losses[0], losses[-1])

    def test_all_heads_train_without_nan(self, tiny_cfg, corpus_tokens):
        params, _ = T.train_base(tiny_cfg, corpus_tokens, steps=8,
                                 log=lambda m: None)
        for kind in ("ctc", "medusa", "hydra"):
            hp, losses = T.train_head(kind, tiny_cfg, params, corpus_tokens,
                                      steps=4, log=lambda m: None)
            assert np.isfinite(losses).all(), kind

    def test_ctc_head_loss_decreases_on_pattern(self, tiny_cfg, corpus_tokens):
        params, _ = T.train_base(tiny_cfg, corpus_tokens, steps=25,
                                 log=lambda m: None)
        hp, losses = T.train_head("ctc", tiny_cfg, params, corpus_tokens,
                                  steps=20, log=lambda m: None)
        assert losses[-1] < losses[0], (losses[0], losses[-1])
