"""Draft heads: shapes, masking invariants, beam properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import constants as C
from compile import heads as H


@pytest.fixture(scope="module")
def ctc_head(tiny_cfg):
    return H.init_ctc_head(tiny_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def emb(tiny_params):
    return tiny_params["emb"]


class TestCtcHead:
    def test_output_is_log_distribution(self, ctc_head, emb, tiny_cfg, rng):
        win = jnp.asarray(rng.normal(size=(2, C.HIDDEN_WIN,
                                           tiny_cfg["d_model"])), jnp.float32)
        lp = H.ctc_head_forward(ctc_head, emb, tiny_cfg, win,
                                jnp.array([4, C.HIDDEN_WIN]))
        assert lp.shape == (2, C.DRAFT_SLOTS, C.DRAFT_VOCAB)
        sums = np.asarray(jnp.exp(lp).sum(-1))
        np.testing.assert_allclose(sums, 1.0, rtol=1e-5)

    def test_invalid_window_rows_are_ignored(self, ctc_head, emb, tiny_cfg, rng):
        d = tiny_cfg["d_model"]
        w = C.HIDDEN_WIN
        tail = rng.normal(size=(1, 5, d)).astype(np.float32)
        win1 = np.zeros((1, w, d), np.float32)
        win1[:, -5:] = tail
        win2 = rng.normal(size=(1, w, d)).astype(np.float32)  # garbage front
        win2[:, -5:] = tail
        lp1 = H.ctc_head_forward(ctc_head, emb, tiny_cfg,
                                 jnp.asarray(win1), jnp.array([5]))
        lp2 = H.ctc_head_forward(ctc_head, emb, tiny_cfg,
                                 jnp.asarray(win2), jnp.array([5]))
        np.testing.assert_allclose(np.asarray(lp1), np.asarray(lp2),
                                   rtol=1e-5, atol=1e-5)

    def test_kernel_and_ref_paths_agree(self, ctc_head, emb, tiny_cfg, rng):
        win = jnp.asarray(rng.normal(size=(1, C.HIDDEN_WIN,
                                           tiny_cfg["d_model"])), jnp.float32)
        wl = jnp.array([C.HIDDEN_WIN])
        a = H.ctc_head_forward(ctc_head, emb, tiny_cfg, win, wl,
                               use_kernel=False)
        b = H.ctc_head_forward(ctc_head, emb, tiny_cfg, win, wl,
                               use_kernel=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


class TestMedusaHead:
    def test_shapes(self, tiny_cfg, emb, rng):
        hp = H.init_medusa_head(tiny_cfg, jax.random.PRNGKey(1))
        h = jnp.asarray(rng.normal(size=(3, tiny_cfg["d_model"])), jnp.float32)
        logits = H.medusa_head_forward(hp, emb, h)
        assert logits.shape == (3, C.MEDUSA_HEADS, C.VOCAB_SIZE)

    def test_near_zero_init_predicts_like_lm_head(self, tiny_cfg, emb, rng):
        # w1 ~ 0.01 => head i output ~ hidden @ emb.T for all i
        hp = {"w1": jnp.zeros((C.MEDUSA_HEADS,) + (tiny_cfg["d_model"],) * 2)}
        h = jnp.asarray(rng.normal(size=(2, tiny_cfg["d_model"])), jnp.float32)
        logits = H.medusa_head_forward(hp, emb, h)
        expect = np.asarray(h @ emb.T)
        for i in range(C.MEDUSA_HEADS):
            np.testing.assert_allclose(np.asarray(logits[:, i]), expect,
                                       rtol=1e-5, atol=1e-5)


class TestHydraHead:
    @pytest.fixture(scope="class")
    def hp(self, tiny_cfg):
        return H.init_hydra_head(tiny_cfg, jax.random.PRNGKey(2))

    def test_beam_shapes_and_order(self, hp, emb, tiny_cfg, rng):
        h = jnp.asarray(rng.normal(size=(2, tiny_cfg["d_model"])), jnp.float32)
        toks, lp = H.hydra_beam_forward(hp, emb, h, jnp.array([5, 6]))
        assert toks.shape == (2, C.HYDRA_BEAMS, C.HYDRA_STEPS)
        assert lp.shape == (2, C.HYDRA_BEAMS)
        assert bool(jnp.all(lp[:, :-1] >= lp[:, 1:])), "beams must be sorted"
        assert bool(jnp.all(lp <= 0.0))

    def test_beams_are_distinct(self, hp, emb, tiny_cfg, rng):
        h = jnp.asarray(rng.normal(size=(1, tiny_cfg["d_model"])), jnp.float32)
        toks, _ = H.hydra_beam_forward(hp, emb, h, jnp.array([5]))
        paths = {tuple(np.asarray(toks[0, i])) for i in range(C.HYDRA_BEAMS)}
        assert len(paths) == C.HYDRA_BEAMS

    def test_top_beam_is_greedy_chain(self, hp, emb, tiny_cfg, rng):
        """With beam width K the best path must dominate the greedy chain."""
        h = jnp.asarray(rng.normal(size=(1, tiny_cfg["d_model"])), jnp.float32)
        toks, lp = H.hydra_beam_forward(hp, emb, h, jnp.array([5]))
        # greedy rollout
        state, tok = h, jnp.array([5])
        greedy_lp = 0.0
        greedy = []
        for _ in range(C.HYDRA_STEPS):
            state, logits = H.hydra_step(hp, emb, state, tok)
            lsm = jax.nn.log_softmax(logits, -1)
            tok = jnp.argmax(lsm, -1)
            greedy_lp += float(lsm[0, tok[0]])
            greedy.append(int(tok[0]))
        assert float(lp[0, 0]) >= greedy_lp - 1e-4


class TestNames:
    def test_head_name_lists_match_inits(self, tiny_cfg):
        assert set(H.ctc_head_names()) == set(
            H.init_ctc_head(tiny_cfg, jax.random.PRNGKey(0)))
        assert set(H.medusa_head_names()) == set(
            H.init_medusa_head(tiny_cfg, jax.random.PRNGKey(0)))
        assert set(H.hydra_head_names()) == set(
            H.init_hydra_head(tiny_cfg, jax.random.PRNGKey(0)))

    def test_shape_tables_match_inits(self, tiny_cfg):
        for shapes, init in [
            (H.ctc_head_shapes(tiny_cfg),
             H.init_ctc_head(tiny_cfg, jax.random.PRNGKey(0))),
            (H.medusa_head_shapes(tiny_cfg),
             H.init_medusa_head(tiny_cfg, jax.random.PRNGKey(0))),
            (H.hydra_head_shapes(tiny_cfg),
             H.init_hydra_head(tiny_cfg, jax.random.PRNGKey(0))),
        ]:
            for k, v in init.items():
                assert tuple(v.shape) == shapes[k], k
