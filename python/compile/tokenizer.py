"""Byte-level BPE tokenizer.

Trained once at build time over the corpus; ``vocab.json`` (merge table +
per-id byte strings) is the interchange with the rust encoder/decoder
(``rust/src/tokenizer``), which reimplements exactly this merge procedure so
both sides produce identical token streams.

Id layout (see constants.py): 0=<pad> 1=<bos> 2=<eos>, 3..258 = raw bytes,
259.. = merges in rank order.
"""

from __future__ import annotations

import collections
import json

from . import constants as C


class ByteBpe:
    def __init__(self, merges: list[tuple[int, int]]):
        assert len(merges) <= C.N_MERGES
        self.merges = merges
        # token id -> bytes
        self.token_bytes: list[bytes] = [b"", b"", b""]
        self.token_bytes += [bytes([i]) for i in range(C.N_BYTES)]
        for a, b in merges:
            self.token_bytes.append(self.token_bytes[a] + self.token_bytes[b])
        # (a, b) -> merged id, in rank order
        self.ranks = {pair: C.N_SPECIAL + C.N_BYTES + i
                      for i, pair in enumerate(merges)}

    @property
    def vocab_size(self) -> int:
        return len(self.token_bytes)

    # ------------------------------------------------------------- encode
    def encode(self, text: str, bos: bool = False, eos: bool = False) -> list[int]:
        ids = [C.N_SPECIAL + b for b in text.encode("utf-8")]
        # repeatedly apply the lowest-rank merge present (classic BPE)
        while len(ids) >= 2:
            best, best_rank = None, None
            for i in range(len(ids) - 1):
                r = self.ranks.get((ids[i], ids[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best, best_rank = i, r
            if best is None:
                break
            merged = self.ranks[(ids[best], ids[best + 1])]
            # merge *all* occurrences of this pair left-to-right
            out, i = [], 0
            while i < len(ids):
                if (i + 1 < len(ids)
                        and ids[i] == ids[best] and ids[i + 1] == ids[best + 1]):
                    out.append(merged)
                    i += 2
                else:
                    out.append(ids[i])
                    i += 1
            ids = out
        if bos:
            ids = [C.BOS_ID] + ids
        if eos:
            ids = ids + [C.EOS_ID]
        return ids

    # ------------------------------------------------------------- decode
    def decode(self, ids: list[int]) -> str:
        buf = b"".join(self.token_bytes[i] for i in ids
                       if 0 <= i < len(self.token_bytes))
        return buf.decode("utf-8", errors="replace")

    # ------------------------------------------------------------- io
    def to_json(self) -> dict:
        return {
            "version": 1,
            "vocab_size": self.vocab_size,
            "specials": {"pad": C.PAD_ID, "bos": C.BOS_ID, "eos": C.EOS_ID},
            "n_bytes": C.N_BYTES,
            "merges": [[a, b] for a, b in self.merges],
            # redundancy for the rust decoder: bytes of every token id
            "token_bytes": [list(b) for b in self.token_bytes],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)

    @classmethod
    def load(cls, path: str) -> "ByteBpe":
        data = json.load(open(path))
        return cls([tuple(m) for m in data["merges"]])


def train_bpe(text: str, n_merges: int = C.N_MERGES) -> ByteBpe:
    """Classic BPE training: repeatedly merge the most frequent adjacent pair.

    Runs on word-ish chunks (split on whitespace, whitespace kept attached to
    the following chunk) to keep counting fast while still allowing merges
    across letters/punctuation inside a chunk.
    """
    # chunk -> count, chunks as tuples of ids
    words: collections.Counter = collections.Counter()
    chunk: list[int] = []
    data = text.encode("utf-8")
    for byte in data:
        tid = C.N_SPECIAL + byte
        if byte in (0x20, 0x0A) and chunk:  # space / newline end a chunk
            chunk.append(tid)
            words[tuple(chunk)] += 1
            chunk = []
        else:
            chunk.append(tid)
    if chunk:
        words[tuple(chunk)] += 1

    merges: list[tuple[int, int]] = []
    word_list = [(list(w), c) for w, c in words.items()]
    for rank in range(n_merges):
        pairs: collections.Counter = collections.Counter()
        for w, c in word_list:
            for i in range(len(w) - 1):
                pairs[(w[i], w[i + 1])] += c
        if not pairs:
            break
        (a, b), cnt = pairs.most_common(1)[0]
        if cnt < 2:
            break
        new_id = C.N_SPECIAL + C.N_BYTES + rank
        merges.append((a, b))
        for w, _ in word_list:
            i = 0
            while i < len(w) - 1:
                if w[i] == a and w[i + 1] == b:
                    w[i:i + 2] = [new_id]
                else:
                    i += 1
    return ByteBpe(merges)
