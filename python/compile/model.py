"""Layer 2 — the base LLM as a JAX compute graph.

Decoder-only transformer (RoPE, RMSNorm, tied LM head; SwiGLU for the "vic"
family, GeLU for "lc2") with two forward entry points:

  * ``lm_forward``   — full-sequence causal forward for training/distill.
  * ``step_forward`` — the serving graph: processes N new tokens against a
    fixed-capacity KV cache under an arbitrary additive attention bias.
    One graph shape serves chunked prefill (N=64), tree verification (N=32,
    bias = the CTC-transformed tree mask) and vanilla decode (N=1).

Weights are *graph parameters* (never baked as constants) in the order given
by ``weight_names`` — the same order is pinned into manifest.json and
tensors.bin for the rust runtime.
"""

from __future__ import annotations

import os
from typing import Dict, List

import jax
import jax.numpy as jnp

from . import constants as C
from .kernels.ref import NEG_INF, attention_ref
from .kernels.tree_attention import tree_attention

Params = Dict[str, jax.Array]

# exported step graphs route attention through the Pallas kernel by default;
# training always uses the jnp reference (autodiff + interpret-mode speed).
USE_KERNEL_ATTN = os.environ.get("CTCD_KERNEL_ATTN", "1") == "1"


# ----------------------------------------------------------------- building blocks
def rmsnorm(x, w, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_cos_sin(pos, dh, theta=C.ROPE_THETA):
    """pos [...,] int -> cos/sin [..., dh/2]."""
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, pos):
    """x [B, T, H, Dh], pos [B, T] -> rotated x."""
    dh = x.shape[-1]
    cos, sin = rope_cos_sin(pos, dh)          # [B, T, dh/2]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def mlp(x, p, i, act):
    up = x @ p[f"layer{i}.w_up"]
    if act == "swiglu":
        gate = x @ p[f"layer{i}.w_gate"]
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p[f"layer{i}.w_down"]


# ----------------------------------------------------------------- params
def weight_names(cfg: dict) -> List[str]:
    """Deterministic weight ordering shared with tensors.bin/manifest."""
    names = ["emb"]
    for i in range(cfg["layers"]):
        names += [f"layer{i}.ln1", f"layer{i}.wq", f"layer{i}.wk",
                  f"layer{i}.wv", f"layer{i}.wo", f"layer{i}.ln2"]
        if cfg["act"] == "swiglu":
            names.append(f"layer{i}.w_gate")
        names += [f"layer{i}.w_up", f"layer{i}.w_down"]
    names.append("ln_f")
    return names


def init_params(cfg: dict, key) -> Params:
    d, f, layers = cfg["d_model"], cfg["d_ff"], cfg["layers"]
    h = cfg["n_heads"] * C.HEAD_DIM
    assert h == d, "model dims assume n_heads * head_dim == d_model"
    p: Params = {}
    keys = jax.random.split(key, 8 * layers + 2)
    ki = iter(keys)

    def dense(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)

    # N(0, 0.02) embedding like GPT; LM head is tied to it
    p["emb"] = jax.random.normal(next(ki), (C.VOCAB_SIZE, d), jnp.float32) * 0.02
    for i in range(layers):
        p[f"layer{i}.ln1"] = jnp.ones((d,))
        p[f"layer{i}.wq"] = dense(next(ki), d, (d, d))
        p[f"layer{i}.wk"] = dense(next(ki), d, (d, d))
        p[f"layer{i}.wv"] = dense(next(ki), d, (d, d))
        p[f"layer{i}.wo"] = dense(next(ki), d, (d, d)) / jnp.sqrt(2 * layers)
        p[f"layer{i}.ln2"] = jnp.ones((d,))
        if cfg["act"] == "swiglu":
            p[f"layer{i}.w_gate"] = dense(next(ki), d, (d, f))
        p[f"layer{i}.w_up"] = dense(next(ki), d, (d, f))
        p[f"layer{i}.w_down"] = dense(next(ki), f, (f, d)) / jnp.sqrt(2 * layers)
    p["ln_f"] = jnp.ones((d,))
    return p


def param_shapes(cfg: dict) -> Dict[str, tuple]:
    d, f = cfg["d_model"], cfg["d_ff"]
    shapes = {"emb": (C.VOCAB_SIZE, d), "ln_f": (d,)}
    for i in range(cfg["layers"]):
        shapes[f"layer{i}.ln1"] = (d,)
        shapes[f"layer{i}.wq"] = (d, d)
        shapes[f"layer{i}.wk"] = (d, d)
        shapes[f"layer{i}.wv"] = (d, d)
        shapes[f"layer{i}.wo"] = (d, d)
        shapes[f"layer{i}.ln2"] = (d,)
        if cfg["act"] == "swiglu":
            shapes[f"layer{i}.w_gate"] = (d, f)
        shapes[f"layer{i}.w_up"] = (d, f)
        shapes[f"layer{i}.w_down"] = (f, d)
    return shapes


# ----------------------------------------------------------------- training forward
def lm_forward(p: Params, cfg: dict, tokens):
    """Causal full-sequence forward. tokens [B, T] -> (logits, hidden)."""
    b, t = tokens.shape
    h_heads, dh = cfg["n_heads"], C.HEAD_DIM
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
    x = p["emb"][tokens]
    causal = jnp.where(jnp.tril(jnp.ones((t, t), bool)), 0.0, NEG_INF)
    bias = jnp.broadcast_to(causal[None], (b, t, t))
    for i in range(cfg["layers"]):
        hn = rmsnorm(x, p[f"layer{i}.ln1"])
        q = apply_rope((hn @ p[f"layer{i}.wq"]).reshape(b, t, h_heads, dh), pos)
        k = apply_rope((hn @ p[f"layer{i}.wk"]).reshape(b, t, h_heads, dh), pos)
        v = (hn @ p[f"layer{i}.wv"]).reshape(b, t, h_heads, dh)
        att = attention_ref(q, k, v, bias).reshape(b, t, -1)
        x = x + att @ p[f"layer{i}.wo"]
        x = x + mlp(rmsnorm(x, p[f"layer{i}.ln2"]), p, i, cfg["act"])
    hidden = rmsnorm(x, p["ln_f"])
    logits = hidden @ p["emb"].T
    return logits, hidden


# ----------------------------------------------------------------- serving forward
def step_forward(p: Params, cfg: dict, kcache, vcache, tokens, pos, bias,
                 use_kernel: bool | None = None):
    """The unified serving graph.

    kcache/vcache: [L, B, Lmax, H, Dh]  (keys stored post-RoPE)
    tokens:        [B, N] int32
    pos:           [B, N] int32 absolute positions (tree nodes carry their
                   CTC-collapsed depth)
    bias:          [B, N, Lmax+N] additive attention bias, built by the rust
                   coordinator: cache-length masking, causal structure for
                   prefill, or the CTC-transformed tree mask for verify.
    returns (logits [B,N,V], k_new [L,B,N,H,Dh], v_new, hidden [B,N,D])
    """
    if use_kernel is None:
        use_kernel = USE_KERNEL_ATTN
    attn = tree_attention if use_kernel else attention_ref
    b, n = tokens.shape
    h_heads, dh = cfg["n_heads"], C.HEAD_DIM
    x = p["emb"][tokens]
    k_news, v_news = [], []
    for i in range(cfg["layers"]):
        hn = rmsnorm(x, p[f"layer{i}.ln1"])
        q = apply_rope((hn @ p[f"layer{i}.wq"]).reshape(b, n, h_heads, dh), pos)
        k = apply_rope((hn @ p[f"layer{i}.wk"]).reshape(b, n, h_heads, dh), pos)
        v = (hn @ p[f"layer{i}.wv"]).reshape(b, n, h_heads, dh)
        k_full = jnp.concatenate([kcache[i], k], axis=1)   # [B, Lmax+N, H, Dh]
        v_full = jnp.concatenate([vcache[i], v], axis=1)
        att = attn(q, k_full, v_full, bias).reshape(b, n, -1)
        x = x + att @ p[f"layer{i}.wo"]
        x = x + mlp(rmsnorm(x, p[f"layer{i}.ln2"]), p, i, cfg["act"])
        k_news.append(k)
        v_news.append(v)
    hidden = rmsnorm(x, p["ln_f"])
    logits = hidden @ p["emb"].T
    return (logits, jnp.stack(k_news), jnp.stack(v_news), hidden)


def make_step_fn(cfg: dict, use_kernel: bool | None = None):
    """Flat-argument wrapper for AOT lowering: (w_0..w_k, kc, vc, tok, pos, bias)."""
    names = weight_names(cfg)

    def fn(*args):
        p = dict(zip(names, args[: len(names)]))
        kcache, vcache, tokens, pos, bias = args[len(names):]
        return step_forward(p, cfg, kcache, vcache, tokens, pos, bias,
                            use_kernel=use_kernel)

    return fn, names


def flat_params(p: Params, cfg: dict) -> List[jax.Array]:
    return [p[n] for n in weight_names(cfg)]
