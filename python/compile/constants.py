"""Shared constants for the CTC-drafter build pipeline.

Everything in this file is mirrored into ``artifacts/manifest.json`` so the
rust coordinator never has to hard-code a shape. Keep this the single source
of truth on the python side.
"""

# ---------------------------------------------------------------- tokenizer
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
N_SPECIAL = 3
N_BYTES = 256
VOCAB_SIZE = 512  # specials + 256 byte tokens + BPE merges
N_MERGES = VOCAB_SIZE - N_SPECIAL - N_BYTES  # 253

# CTC blank lives *outside* the base vocabulary: the draft head predicts over
# VOCAB_SIZE + 1 symbols, the base model only ever sees VOCAB_SIZE.
BLANK_ID = VOCAB_SIZE
DRAFT_VOCAB = VOCAB_SIZE + 1

# ---------------------------------------------------------------- serving shapes
LMAX = 384          # KV-cache capacity per sequence (tokens)
TREE_N = 32         # token-tree nodes verified per speculative step
PREFILL_N = 64      # chunked-prefill width (tokens per step-graph call)
DRAFT_SLOTS = 8     # CTC alignment length T' (draft positions incl. blanks)
CTC_TARGET_U = 6    # max collapsed target length used in the CTC loss
HIDDEN_WIN = 16     # trailing hidden-state window fed to the CTC draft module
MEDUSA_HEADS = 4    # offsets predicted by the Medusa baseline head
HYDRA_STEPS = 4     # sequential depth of the Hydra baseline head
HYDRA_BEAMS = 8     # beam width of the in-graph Hydra expansion
BATCH_SIZES = (1, 4)
STEP_NS = (1, TREE_N, PREFILL_N)

# ---------------------------------------------------------------- training
TRAIN_SEQ = 96      # training sequence length
TRAIN_BATCH = 8
LR_BASE = 3e-4
LR_HEAD = 3e-4      # paper uses 3e-5 on a pretrained 7B; our from-scratch
                    # models want a larger step. Grad-clip matches the paper.
GRAD_CLIP = 0.5     # paper: "setting the clipping threshold to 0.5"
ROPE_THETA = 10000.0

# ---------------------------------------------------------------- model zoo
# Analogs for the paper's base models (see DESIGN.md §2). All head_dim=32.
MODELS = {
    "vic-tiny": dict(family="vic", analog="Vicuna-7B", layers=2, d_model=128,
                     n_heads=4, d_ff=384, act="swiglu"),
    "vic-small": dict(family="vic", analog="Vicuna-13B", layers=4, d_model=160,
                      n_heads=5, d_ff=480, act="swiglu"),
    "vic-base": dict(family="vic", analog="Vicuna-33B", layers=6, d_model=192,
                     n_heads=6, d_ff=576, act="swiglu"),
    "lc2-tiny": dict(family="lc2", analog="LLaMA-2-Chat-7B", layers=2,
                     d_model=128, n_heads=4, d_ff=384, act="gelu"),
    "lc2-small": dict(family="lc2", analog="LLaMA-2-Chat-13B", layers=4,
                      d_model=160, n_heads=5, d_ff=480, act="gelu"),
}
HEAD_DIM = 32

# Chat templates per family (the "distinct inference paradigms" of Fig 4).
CHAT_TEMPLATES = {
    "vic": ("USER: {q}\nASSISTANT: {a}\n", "USER: {q}\nASSISTANT:"),
    "lc2": ("[INST] {q} [/INST] {a}\n", "[INST] {q} [/INST]"),
}

MTBENCH_CATEGORIES = (
    "writing", "roleplay", "reasoning", "math",
    "coding", "extraction", "stem", "humanities",
)

MANIFEST_VERSION = 1
TENSORS_MAGIC = b"CTCW"
