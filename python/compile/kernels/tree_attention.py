"""Pallas tree-attention kernel — the verification hot spot (Layer 1).

Computes multi-head attention of N query tokens (the token-tree nodes, or a
prefill chunk) against M = cache + tree key/value positions, under an
arbitrary additive attention bias. The bias is where the paper's *CTC
Transform* lands: the rust coordinator collapses raw candidate sequences
(removing repeats/blanks) and patches exactly this mask so removed positions
become invisible during verification.

TPU mapping (see DESIGN.md §6): the grid iterates (batch, head, q-block);
each step streams K/V in KBLK-sized tiles HBM→VMEM and maintains a running
(flash-style) softmax so the full [N, M] score matrix never materializes.
On CPU we execute with interpret=True; the BlockSpec structure is what a
real Mosaic lowering would pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9
KBLK = 64  # key/value tile (lanes-friendly on TPU: multiple of 128 bytes f32)


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale, kblk):
    """One (b, h, q-block) grid step.

    q_ref:    [QBLK, Dh]
    k_ref:    [M, Dh]       (full rows for this (b,h); tiled by the loop)
    v_ref:    [M, Dh]
    bias_ref: [QBLK, M]
    o_ref:    [QBLK, Dh]
    """
    qblk, dh = q_ref.shape
    m_total = k_ref.shape[0]
    n_kblk = m_total // kblk

    q = q_ref[...].astype(jnp.float32) * scale

    def body(i, carry):
        acc, row_max, row_sum = carry
        k = pl.load(k_ref, (pl.ds(i * kblk, kblk), slice(None)))
        v = pl.load(v_ref, (pl.ds(i * kblk, kblk), slice(None)))
        b = pl.load(bias_ref, (slice(None), pl.ds(i * kblk, kblk)))
        s = q @ k.T + b                                   # [QBLK, KBLK]
        new_max = jnp.maximum(row_max, jnp.max(s, axis=-1))
        # guard fully-masked rows: keep the running max finite
        new_max = jnp.maximum(new_max, NEG_INF / 2)
        correction = jnp.exp(row_max - new_max)
        p = jnp.exp(s - new_max[:, None])
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        acc = acc * correction[:, None] + p @ v
        row_sum = row_sum * correction + jnp.sum(p, axis=-1)
        return acc, new_max, row_sum

    acc0 = jnp.zeros((qblk, dh), jnp.float32)
    max0 = jnp.full((qblk,), NEG_INF, jnp.float32)
    sum0 = jnp.zeros((qblk,), jnp.float32)
    acc, _, row_sum = jax.lax.fori_loop(0, n_kblk, body, (acc0, max0, sum0))
    o_ref[...] = (acc / jnp.maximum(row_sum, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_attention(q, k, v, bias, interpret=True):
    """Masked attention via the Pallas kernel.

    q:    [B, N, H, Dh]
    k, v: [B, M, H, Dh]
    bias: [B, N, M] additive (-1e9 = masked)
    out:  [B, N, H, Dh]
    """
    b, n, h, dh = q.shape
    m = k.shape[1]
    scale = 1.0 / (dh ** 0.5)

    # pad M to a KBLK multiple; padded keys are masked by the padded bias
    m_pad = (m + KBLK - 1) // KBLK * KBLK
    if m_pad != m:
        pad = m_pad - m
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, 0), (0, pad)),
                       constant_values=NEG_INF)

    qblk = n if n <= 32 else 32
    assert n % qblk == 0, (n, qblk)
    grid = (b, h, n // qblk)

    # layout: put heads in front of seq so each grid step reads a contiguous row
    qt = q.transpose(0, 2, 1, 3)   # [B, H, N, Dh]
    kt = k.transpose(0, 2, 1, 3)   # [B, H, M, Dh]
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, kblk=KBLK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, qblk, dh), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, m_pad, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, None, m_pad, dh), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((None, qblk, m_pad), lambda bi, hi, qi: (bi, qi, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, qblk, dh),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, n, dh), q.dtype),
        interpret=interpret,
    )(qt, kt, vt, bias)
    return out.transpose(0, 2, 1, 3)  # back to [B, N, H, Dh]


def vmem_report(n, m, dh, qblk=None, kblk=KBLK):
    """Static VMEM-footprint estimate for DESIGN.md §Perf (bytes, f32).

    What a real Mosaic lowering would hold resident per grid step:
    q tile + 2 double-buffered k/v tiles + bias tile + accumulator.
    """
    qblk = qblk or (n if n <= 32 else 32)
    q_tile = qblk * dh * 4
    kv_tiles = 2 * 2 * kblk * dh * 4          # k+v, double-buffered
    bias_tile = qblk * kblk * 4
    acc = qblk * dh * 4 + 2 * qblk * 4
    total = q_tile + kv_tiles + bias_tile + acc
    # MXU utilization proxy: fraction of the 128x128 systolic array covered
    mxu = min(qblk, 128) * min(dh, 128) / (128 * 128)
    return {"vmem_bytes": total, "mxu_tile_cover": mxu,
            "grid_steps_per_bh": (m + kblk - 1) // kblk}
