"""Pallas CTC forward (α-recursion) kernel — Layer 1.

The sequence-level CTC objective (paper Eq. 6–8) sums the probability of
every alignment that collapses to the target. The α-recursion DP over the
blank-extended target lattice computes that sum in O(T·S).

Two consumers:
  * training uses the autodiff-able jnp reference (kernels/ref.py); this
    kernel is asserted equal to it by pytest/hypothesis,
  * the standalone ``ctc_score`` artifact (see aot.py) exposes the kernel to
    the rust coordinator for draft-candidate rescoring and for the
    micro-benchmarks.

The lattice dimension S = 2U+1 is tiny (13 for U=6); the kernel therefore
tiles over the *batch* and keeps the whole lattice in registers/VMEM, with
the T-step scan as the sequential dimension — the same structure a Mosaic
lowering would pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ctc_extend_targets

NEG_INF = -1e9


def _ctc_kernel(logp_ref, ext_ref, vlen_ref, out_ref):
    """One batch element per grid step.

    logp_ref: [T, V+1] log-probs
    ext_ref:  [S] blank-extended targets (S = 2U+1)
    vlen_ref: [1] valid lattice length (2*tgt_len+1)
    out_ref:  [1] nll
    """
    t_steps = logp_ref.shape[0]
    s = ext_ref.shape[0]
    ext = ext_ref[...]
    valid_s = vlen_ref[0]
    idx = jax.lax.iota(jnp.int32, s)

    blank = logp_ref.shape[1] - 1  # blank is always the last symbol
    skip_ok = jnp.concatenate([
        jnp.zeros((2,), dtype=bool),
        (ext[2:] != blank) & (ext[2:] != ext[:-2]),
    ])

    lp0 = logp_ref[0, :]
    alpha = jnp.where(idx == 0, lp0[ext[0]], NEG_INF)
    alpha = jnp.where((idx == 1) & (valid_s > 1), lp0[ext[1]], alpha)

    def step(t, alpha):
        lp_t = logp_ref[t, :][ext]                       # gather [S]
        prev1 = jnp.concatenate([jnp.full((1,), NEG_INF), alpha[:-1]])
        prev2 = jnp.concatenate([jnp.full((2,), NEG_INF), alpha[:-2]])
        prev2 = jnp.where(skip_ok, prev2, NEG_INF)
        m = jnp.maximum(alpha, jnp.maximum(prev1, prev2))
        m_safe = jnp.maximum(m, NEG_INF / 2)
        tot = (jnp.exp(alpha - m_safe) + jnp.exp(prev1 - m_safe)
               + jnp.exp(prev2 - m_safe))
        new = m_safe + jnp.log(jnp.maximum(tot, 1e-30)) + lp_t
        new = jnp.where(idx < valid_s, new, NEG_INF)
        return new

    alpha = jax.lax.fori_loop(1, t_steps, step, alpha)

    last_i = jnp.maximum(valid_s - 1, 0)
    last = jnp.sum(jnp.where(idx == last_i, alpha, 0.0))
    last_ok = jnp.sum(jnp.where(idx == last_i, 1.0, 0.0)) > 0
    last = jnp.where(last_ok, last, NEG_INF)
    prev_i = valid_s - 2
    prev = jnp.sum(jnp.where(idx == prev_i, alpha, 0.0))
    prev = jnp.where(valid_s >= 2, prev, NEG_INF)
    m = jnp.maximum(last, prev)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    ll = m_safe + jnp.log(jnp.maximum(
        jnp.exp(last - m_safe) + jnp.exp(prev - m_safe), 1e-30))
    out_ref[0] = -ll


@functools.partial(jax.jit, static_argnames=("blank_id", "interpret"))
def ctc_neg_logp(logp, targets, tgt_len, blank_id, interpret=True):
    """Batched CTC nll via the Pallas kernel.

    logp:    [B, T, V+1] log-probabilities (blank must be the LAST column)
    targets: [B, U] target ids
    tgt_len: [B] valid target lengths
    returns  [B] nll
    """
    assert blank_id == logp.shape[-1] - 1, "kernel expects blank last"
    b, t_steps, _ = logp.shape
    ext = ctc_extend_targets(targets.astype(jnp.int32), blank_id)  # [B, S]
    s = ext.shape[-1]
    vlen = (2 * tgt_len.astype(jnp.int32) + 1).reshape(b, 1)

    out = pl.pallas_call(
        _ctc_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, t_steps, logp.shape[-1]), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s), lambda i: (i, 0)),
            pl.BlockSpec((None, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(logp.astype(jnp.float32), ext, vlen)
    return out[:, 0]
