"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest/hypothesis sweep shapes and
assert the Pallas (interpret=True) kernels match these to float tolerance.
They are also what the training graphs use when autodiff is needed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def attention_ref(q, k, v, bias, scale=None):
    """Masked multi-head attention, the oracle for tree_attention.

    q:    [B, N, H, Dh]
    k, v: [B, M, H, Dh]
    bias: [B, N, M] additive mask (-1e9 for masked)
    out:  [B, N, H, Dh]
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bnhd,bmhd->bhnm", q, k) * scale
    scores = scores + bias[:, None, :, :]
    # safe softmax: rows that are fully masked produce zeros, not NaN
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF / 2)
    e = jnp.exp(scores - m)
    e = jnp.where(scores <= NEG_INF / 2, 0.0, e)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(denom, 1e-30)
    return jnp.einsum("bhnm,bmhd->bnhd", p, v)


def ctc_extend_targets(targets, blank_id):
    """Interleave blanks: y_1..y_U -> (eps, y_1, eps, ..., y_U, eps)."""
    u = targets.shape[-1]
    ext = jnp.full(targets.shape[:-1] + (2 * u + 1,), blank_id,
                   dtype=targets.dtype)
    return ext.at[..., 1::2].set(targets)


def ctc_neg_logp_ref(logp, targets, tgt_len, blank_id):
    """CTC negative log-likelihood (Graves et al. 2006), single example.

    logp:    [T, V+1] log-probabilities per alignment slot
    targets: [U] collapsed target ids (padded arbitrarily past tgt_len)
    tgt_len: scalar int, number of valid targets (may be 0)
    Returns scalar nll = -log sum_{a in beta^-1(y)} p(a).
    """
    u = targets.shape[0]
    ext = ctc_extend_targets(targets, blank_id)       # [2U+1]
    s = 2 * u + 1
    valid_s = 2 * tgt_len + 1

    ext_lp = logp[:, ext]                              # [T, S]

    # can we skip from s-2 to s (only when ext[s] != blank and != ext[s-2])
    skip_ok = jnp.concatenate([
        jnp.zeros((2,), dtype=bool),
        (ext[2:] != blank_id) & (ext[2:] != ext[:-2]),
    ])

    neg = jnp.float32(NEG_INF)
    idx = jnp.arange(s)
    alpha = jnp.where(idx == 0, ext_lp[0, 0], neg)
    alpha = jnp.where((idx == 1) & (valid_s > 1), ext_lp[0, 1], alpha)

    def step(alpha, lp_t):
        prev1 = jnp.concatenate([jnp.array([neg]), alpha[:-1]])
        prev2 = jnp.concatenate([jnp.array([neg, neg]), alpha[:-2]])
        prev2 = jnp.where(skip_ok, prev2, neg)
        stacked = jnp.stack([alpha, prev1, prev2])
        new = jax.nn.logsumexp(stacked, axis=0) + lp_t
        new = jnp.where(idx < valid_s, new, neg)
        return new, None

    alpha, _ = jax.lax.scan(step, alpha, ext_lp[1:])
    # final prob mass sits on the last two lattice states
    last = alpha[jnp.maximum(valid_s - 1, 0)]
    last2 = jnp.where(valid_s >= 2, alpha[jnp.maximum(valid_s - 2, 0)], neg)
    ll = jnp.logaddexp(last, last2)
    return -ll


def ctc_neg_logp_batch_ref(logp, targets, tgt_len, blank_id):
    """vmapped oracle: logp [B,T,V+1], targets [B,U], tgt_len [B] -> [B]."""
    return jax.vmap(lambda a, b, c: ctc_neg_logp_ref(a, b, c, blank_id))(
        logp, targets, tgt_len)


def ctc_brute_force_neg_logp(logp, targets, blank_id):
    """Exponential enumeration of all alignments — tiny cases only.

    Ground truth for testing the DP: sums p(a) over every alignment a of
    length T whose collapse equals `targets`.
    """
    import itertools

    import numpy as np

    logp = np.asarray(logp)
    t_steps, vocab = logp.shape
    tgt = [int(x) for x in targets]

    def collapse(seq):
        out, prev = [], None
        for s in seq:
            if s != prev and s != blank_id:
                out.append(s)
            prev = s
        return out

    total = -np.inf
    for a in itertools.product(range(vocab), repeat=t_steps):
        if collapse(list(a)) == tgt:
            lp = sum(logp[t, s] for t, s in enumerate(a))
            total = np.logaddexp(total, lp)
    return -total
