"""Draft modules (Layer 2): CTC-drafter head + Medusa/Hydra baselines.

CTC head (the paper's contribution, §3.1): ONE transformer layer whose
queries are learned "slot" embeddings (one per alignment position, S=8) that
cross-attend to the trailing window of base-model hidden states. Output
distributions live over V+1 symbols (base vocab + blank, blank LAST) and are
trained with the sequence-level CTC loss.

Medusa head (baseline, Cai et al.): K independent residual-SiLU linear
heads, offset i predicts the token i+1 steps ahead. Token-level CE loss.

Hydra head (baseline, Ankner et al.): a sequentially-dependent MLP that
consumes the previous draft token's embedding; the AOT graph runs the beam
expansion *inside* JAX so the rust hot path gets whole candidate beams in
one call.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from . import constants as C
from .kernels.ref import NEG_INF, attention_ref
from .kernels.tree_attention import tree_attention
from .model import rmsnorm

Params = Dict[str, jax.Array]


# ================================================================= CTC head
def ctc_head_names() -> List[str]:
    return ["slot_emb", "ln_q", "wq", "wk", "wv", "wo",
            "ln2", "w_up", "w_down", "ln_f", "w_blank"]


def init_ctc_head(cfg: dict, key) -> Params:
    d = cfg["d_model"]
    f = 2 * d
    ks = jax.random.split(key, 8)

    def dense(k, fan_in, shape):
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)

    return {
        "slot_emb": jax.random.normal(ks[0], (C.DRAFT_SLOTS, d)) * 0.02,
        "ln_q": jnp.ones((d,)),
        "wq": dense(ks[1], d, (d, d)),
        "wk": dense(ks[2], d, (d, d)),
        "wv": dense(ks[3], d, (d, d)),
        "wo": dense(ks[4], d, (d, d)),
        "ln2": jnp.ones((d,)),
        "w_up": dense(ks[5], d, (d, f)),
        "w_down": dense(ks[6], f, (f, d)),
        "ln_f": jnp.ones((d,)),
        "w_blank": dense(ks[7], d, (d,)),
    }


def ctc_head_shapes(cfg: dict) -> Dict[str, tuple]:
    d = cfg["d_model"]
    return {"slot_emb": (C.DRAFT_SLOTS, d), "ln_q": (d,), "wq": (d, d),
            "wk": (d, d), "wv": (d, d), "wo": (d, d), "ln2": (d,),
            "w_up": (d, 2 * d), "w_down": (2 * d, d), "ln_f": (d,),
            "w_blank": (d,)}


def ctc_head_forward(hp: Params, emb, cfg: dict, window, win_len,
                     use_kernel: bool = False):
    """window [B, W, D] (right-aligned: the last win_len rows are valid,
    window[:, -1] is the hidden state of the newest accepted token).
    Returns slot log-probs [B, S, V+1] (blank last).
    """
    b, w, d = window.shape
    h_heads, dh = cfg["n_heads"], C.HEAD_DIM
    s = C.DRAFT_SLOTS
    h_last = window[:, -1]
    x0 = hp["slot_emb"][None] + h_last[:, None, :]          # [B, S, D]
    hn = rmsnorm(x0, hp["ln_q"])
    q = (hn @ hp["wq"]).reshape(b, s, h_heads, dh)
    k = (window @ hp["wk"]).reshape(b, w, h_heads, dh)
    v = (window @ hp["wv"]).reshape(b, w, h_heads, dh)
    # right-aligned validity mask
    j = jnp.arange(w)[None, :]
    valid = j >= (w - win_len[:, None])
    bias = jnp.where(valid, 0.0, NEG_INF)[:, None, :]        # [B, 1, W]
    bias = jnp.broadcast_to(bias, (b, s, w))
    attn = tree_attention if use_kernel else attention_ref
    att = attn(q, k, v, bias).reshape(b, s, d)
    x = x0 + att @ hp["wo"]
    x = x + jax.nn.silu(rmsnorm(x, hp["ln2"]) @ hp["w_up"]) @ hp["w_down"]
    h = rmsnorm(x, hp["ln_f"])
    logit_v = h @ emb.T                                       # [B, S, V]
    logit_b = (h @ hp["w_blank"])[..., None]                  # [B, S, 1]
    return jax.nn.log_softmax(jnp.concatenate([logit_v, logit_b], -1), -1)


def make_ctc_draft_fn(cfg: dict, use_kernel: bool = True):
    """Flat-arg AOT wrapper: (head w..., emb, window, win_len) -> logp."""
    names = ctc_head_names()

    def fn(*args):
        hp = dict(zip(names, args[: len(names)]))
        emb, window, win_len = args[len(names):]
        return (ctc_head_forward(hp, emb, cfg, window, win_len,
                                 use_kernel=use_kernel),)

    return fn, names


# ================================================================= Medusa head
def medusa_head_names() -> List[str]:
    return ["w1"]


def init_medusa_head(cfg: dict, key) -> Params:
    d = cfg["d_model"]
    # residual blocks initialized near-zero so head starts as identity
    return {"w1": jax.random.normal(key, (C.MEDUSA_HEADS, d, d)) * 0.01}


def medusa_head_shapes(cfg: dict) -> Dict[str, tuple]:
    d = cfg["d_model"]
    return {"w1": (C.MEDUSA_HEADS, d, d)}


def medusa_head_forward(hp: Params, emb, hidden):
    """hidden [B, D] -> logits [B, K, V] (head i predicts offset i+1)."""
    h = hidden[:, None, :] + jax.nn.silu(
        jnp.einsum("bd,kde->bke", hidden, hp["w1"]))
    return h @ emb.T


def make_medusa_draft_fn(cfg: dict):
    names = medusa_head_names()

    def fn(*args):
        hp = dict(zip(names, args[: len(names)]))
        emb, hidden = args[len(names):]
        return (medusa_head_forward(hp, emb, hidden),)

    return fn, names


# ================================================================= Hydra head
def hydra_head_names() -> List[str]:
    return ["w1", "w2"]


def init_hydra_head(cfg: dict, key) -> Params:
    d = cfg["d_model"]
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (2 * d, d)) / jnp.sqrt(2 * d),
        "w2": jax.random.normal(k2, (d, d)) * 0.01,
    }


def hydra_head_shapes(cfg: dict) -> Dict[str, tuple]:
    d = cfg["d_model"]
    return {"w1": (2 * d, d), "w2": (d, d)}


def topk_manual(x, k):
    """top-k via iterated argmax — `lax.top_k` lowers to an HLO `topk` op
    (with a `largest` attribute) that xla_extension 0.5.1's text parser
    rejects, so draft graphs roll their own. x [..., n] -> (vals, idxs)."""
    vals, idxs = [], []
    cur = x
    n = x.shape[-1]
    for _ in range(k):
        i = jnp.argmax(cur, axis=-1)
        v = jnp.take_along_axis(cur, i[..., None], axis=-1)[..., 0]
        vals.append(v)
        idxs.append(i)
        mask = jax.nn.one_hot(i, n, dtype=bool)
        cur = jnp.where(mask, -jnp.inf, cur)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def hydra_step(hp: Params, emb, state, tok):
    """state [..., D], tok [...] int -> (new_state, logits [..., V])."""
    inp = jnp.concatenate([state, emb[tok]], axis=-1)
    u = state + jax.nn.silu(inp @ hp["w1"]) @ hp["w2"]
    return u, u @ emb.T


def hydra_beam_forward(hp: Params, emb, hidden, base_tok):
    """In-graph beam expansion.

    hidden [B, D] (state at the newest accepted token), base_tok [B] (that
    token's id). Returns (beam_tokens [B, K, S], beam_logp [B, K]) — K beams
    of S sequentially-drafted tokens.
    """
    b, d = hidden.shape
    k_beams, steps = C.HYDRA_BEAMS, C.HYDRA_STEPS
    state, logits = hydra_step(hp, emb, hidden, base_tok)
    logp = jax.nn.log_softmax(logits, -1)                   # [B, V]
    top_lp, top_tok = topk_manual(logp, k_beams)            # [B, K]
    states = jnp.broadcast_to(state[:, None, :], (b, k_beams, d))
    toks = jnp.zeros((b, k_beams, steps), jnp.int32)
    toks = toks.at[:, :, 0].set(top_tok)
    beam_lp = top_lp
    for step_i in range(1, steps):
        prev_tok = toks[:, :, step_i - 1]
        states, logits = hydra_step(hp, emb, states, prev_tok)  # [B,K,V]
        lp = jax.nn.log_softmax(logits, -1)
        cand = beam_lp[:, :, None] + lp                     # [B, K, V]
        v = cand.shape[-1]
        flat = cand.reshape(b, k_beams * v)
        beam_lp, idx = topk_manual(flat, k_beams)           # [B, K]
        parent = idx // v
        tok = (idx % v).astype(jnp.int32)
        toks = jnp.take_along_axis(toks, parent[:, :, None], axis=1)
        toks = toks.at[:, :, step_i].set(tok)
        states = jnp.take_along_axis(states, parent[:, :, None], axis=1)
    return toks, beam_lp


def make_hydra_draft_fn(cfg: dict):
    names = hydra_head_names()

    def fn(*args):
        hp = dict(zip(names, args[: len(names)]))
        emb, hidden, base_tok = args[len(names):]
        toks, lp = hydra_beam_forward(hp, emb, hidden, base_tok)
        return (toks, lp)

    return fn, names
