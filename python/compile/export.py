"""Artifact writers: tensors.bin, vocab.json, manifest.json, HLO text.

tensors.bin layout (little-endian), mirrored by ``rust/src/runtime/weights.rs``
and by ``read_tensors`` below (used in tests):

  magic  b"CTCW" | u32 version | u32 tensor_count
  per tensor:
    u16 name_len | name (utf-8)
    u8 dtype (0 = f32, 1 = i32)
    u8 ndim | u32 dims[ndim]
    u64 payload_bytes | payload (raw LE)
"""

from __future__ import annotations

import json
import os
import struct
from typing import Dict, List

import numpy as np

from . import constants as C

DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
CODE_DTYPES = {0: np.float32, 1: np.int32}


def write_tensors(path: str, tensors: Dict[str, np.ndarray],
                  order: List[str]) -> None:
    assert set(order) == set(tensors), (sorted(order), sorted(tensors))
    with open(path, "wb") as f:
        f.write(C.TENSORS_MAGIC)
        f.write(struct.pack("<II", 1, len(order)))
        for name in order:
            arr = np.ascontiguousarray(tensors[name])
            code = DTYPE_CODES[arr.dtype]
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            payload = arr.tobytes()
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


def read_tensors(path: str) -> Dict[str, np.ndarray]:
    """Python mirror of the rust loader — used by tests to validate files."""
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == C.TENSORS_MAGIC, magic
        version, count = struct.unpack("<II", f.read(8))
        assert version == 1
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            data = np.frombuffer(f.read(nbytes), dtype=CODE_DTYPES[code])
            out[name] = data.reshape(dims)
    return out


def to_hlo_text(lowered) -> str:
    """HLO *text* interchange (not .serialize(); see DESIGN.md §1)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def arg_spec(name: str, shape, dtype: str) -> dict:
    return {"name": name, "shape": list(shape), "dtype": dtype}


def write_manifest(path: str, manifest: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, path)


def load_manifest(path: str) -> dict:
    return json.load(open(path))
