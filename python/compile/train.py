"""Build-time training: base LMs and draft heads.

Pipeline per model (paper §3.2):
  1. train the base LM (CE, AdamW hand-rolled, grad-clip 0.5, cosine LR),
  2. distill: teacher greedy argmax over the corpus gives Y_distill
     (Eq. 3–5) — computed on the fly per batch, base frozen,
  3. train heads on the frozen base's hidden states:
       CTC head    — sequence-level CTC loss over the next-U distilled
                     tokens at every position (Eq. 6–8),
       Medusa head — per-offset CE,
       Hydra head  — teacher-forced sequential CE.

Step counts come from env (CTCD_STEPS_BASE / CTCD_STEPS_HEAD) so tests run
in seconds and the full build is reproducible; EXPERIMENTS.md records the
counts used for the shipped artifacts.
"""

from __future__ import annotations

import functools
import os
import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import constants as C
from . import heads as H
from . import model as M
from .kernels.ref import ctc_neg_logp_batch_ref

STEPS_BASE = int(os.environ.get("CTCD_STEPS_BASE", "220"))
STEPS_HEAD = int(os.environ.get("CTCD_STEPS_HEAD", "160"))


# ----------------------------------------------------------------- optimizer
def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def global_norm(tree):
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(g * g), tree))
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, state, lr, clip=C.GRAD_CLIP,
                 b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], grads)
    bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t

    def upd(p, mm, vv):
        step = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        return p - lr * (step + wd * p)

    params = jax.tree.map(upd, params, m, v)
    return params, {"m": m, "v": v, "t": t}


def cosine_lr(step, total, base_lr, warmup=20):
    warm = base_lr * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


# ----------------------------------------------------------------- data
class Batcher:
    """Deterministic sampler of [B, T+1] windows from a token stream."""

    def __init__(self, tokens: np.ndarray, batch: int, seq: int, seed: int):
        assert len(tokens) > seq + 1, "corpus too small"
        self.tokens = tokens
        self.batch, self.seq = batch, seq
        self.rng = np.random.default_rng(seed)

    def next(self) -> np.ndarray:
        starts = self.rng.integers(0, len(self.tokens) - self.seq - 1,
                                   size=self.batch)
        return np.stack([self.tokens[s:s + self.seq + 1] for s in starts])


# ----------------------------------------------------------------- base LM
def make_base_loss(cfg):
    def loss_fn(params, batch):
        x, y = batch[:, :-1], batch[:, 1:]
        logits, _ = M.lm_forward(params, cfg, x)
        lp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(lp, y[..., None], -1)[..., 0]
        return jnp.mean(nll)
    return loss_fn


def train_base(cfg: dict, tokens: np.ndarray, seed: int = 0,
               steps: int | None = None, log: Callable = print):
    steps = steps or STEPS_BASE
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    opt = adamw_init(params)
    loss_fn = make_base_loss(cfg)

    @jax.jit
    def train_step(params, opt, batch, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = cosine_lr(step, steps, C.LR_BASE)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    batcher = Batcher(tokens, C.TRAIN_BATCH, C.TRAIN_SEQ, seed + 1)
    losses, t0 = [], time.time()
    for step in range(steps):
        batch = jnp.asarray(batcher.next())
        params, opt, loss = train_step(params, opt, batch, step)
        losses.append(float(loss))
        if step % 25 == 0 or step == steps - 1:
            log(f"  base step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    return params, losses


# ----------------------------------------------------------------- distill + windows
def make_teacher_fn(cfg):
    @jax.jit
    def teacher(params, x):
        logits, hidden = M.lm_forward(params, cfg, x)
        return jnp.argmax(logits, -1).astype(jnp.int32), hidden
    return teacher


def hidden_windows(hidden):
    """hidden [B, T, D] -> right-aligned windows [B, T, W, D].

    window[b, t, W-1-j] = hidden[b, t-j] (zeros before the sequence start);
    matches the rust coordinator's ring buffer layout exactly.
    """
    b, t, d = hidden.shape
    w = C.HIDDEN_WIN
    pad = jnp.pad(hidden, ((0, 0), (w - 1, 0), (0, 0)))
    idx = jnp.arange(t)[:, None] + jnp.arange(w)[None, :]   # [T, W]
    return pad[:, idx, :]                                    # [B, T, W, D]


def next_token_targets(labels, u=C.CTC_TARGET_U):
    """labels [B, T] (teacher argmax = token at t+1 under teacher forcing).

    The draft module predicts tokens *after* the base token (paper §3.3:
    "probability distributions of different positions after base token").
    labels[t] IS the base token at position t+1, so the CTC target for
    position t starts one further: labels[t+1], ..., labels[t+u].
    Returns (targets [B, T, U], tgt_len [B, T]).
    """
    b, t = labels.shape
    pad = jnp.pad(labels, ((0, 0), (0, u + 1)), constant_values=C.PAD_ID)
    idx = jnp.arange(t)[:, None] + 1 + jnp.arange(u)[None, :]
    targets = pad[:, idx]                                    # [B, T, U]
    tgt_len = jnp.clip(t - 1 - jnp.arange(t), 0, u)          # [T]
    tgt_len = jnp.broadcast_to(tgt_len[None], (b, t))
    return targets.astype(jnp.int32), tgt_len.astype(jnp.int32)


# ----------------------------------------------------------------- CTC head training
def make_ctc_head_loss(cfg):
    def loss_fn(hp, emb, hidden, labels):
        b, t, d = hidden.shape
        wins = hidden_windows(hidden)                        # [B, T, W, D]
        win_len = jnp.minimum(jnp.arange(t) + 1, C.HIDDEN_WIN)
        win_len = jnp.broadcast_to(win_len[None], (b, t))
        flat_w = wins.reshape(b * t, C.HIDDEN_WIN, d)
        flat_l = win_len.reshape(b * t)
        logp = H.ctc_head_forward(hp, emb, cfg, flat_w, flat_l)  # [BT, S, V+1]
        targets, tgt_len = next_token_targets(labels)
        nll = ctc_neg_logp_batch_ref(
            logp, targets.reshape(b * t, -1), tgt_len.reshape(b * t),
            C.BLANK_ID)
        # exclude positions with no target, and positions whose target cannot
        # be aligned at all (too many adjacent repeats for T'=S slots ->
        # nll ~ 1e9) — they carry no learning signal, only blow up the loss
        weight = ((tgt_len.reshape(b * t) > 0) & (nll < 1e6)).astype(jnp.float32)
        return jnp.sum(nll * weight) / jnp.maximum(jnp.sum(weight), 1.0)
    return loss_fn


def make_medusa_head_loss(cfg):
    def loss_fn(hp, emb, hidden, labels):
        b, t, d = hidden.shape
        logits = H.medusa_head_forward(hp, emb, hidden.reshape(b * t, d))
        logits = logits.reshape(b, t, C.MEDUSA_HEADS, -1)
        lp = jax.nn.log_softmax(logits, -1)
        total, denom = 0.0, 0.0
        for i in range(C.MEDUSA_HEADS):
            # head i predicts the token (i+2) ahead of input t: labels[t+1+i]
            off = i + 1
            tgt = labels[:, off:]
            pred = lp[:, : t - off, i, :]
            nll = -jnp.take_along_axis(pred, tgt[..., None], -1)[..., 0]
            total = total + jnp.sum(nll)
            denom = denom + nll.size
        return total / denom
    return loss_fn


def make_hydra_head_loss(cfg):
    def loss_fn(hp, emb, hidden, labels):
        # teacher-forced chain: state_0 = hidden[t], tok_0 = labels[t]
        # (the base token), predict labels[t+i] for i=1..HYDRA_STEPS.
        b, t, d = hidden.shape
        state = hidden
        tok = labels
        total, denom = 0.0, 0.0
        for i in range(1, C.HYDRA_STEPS + 1):
            state, logits = H.hydra_step(hp, emb, state, tok)
            lp = jax.nn.log_softmax(logits, -1)
            tgt = labels[:, i:]
            nll = -jnp.take_along_axis(lp[:, : t - i], tgt[..., None], -1)[..., 0]
            total = total + jnp.sum(nll)
            denom = denom + nll.size
            tok = jnp.pad(labels[:, i:], ((0, 0), (0, i)))  # next teacher tok
        return total / denom
    return loss_fn


HEAD_KINDS = {
    "ctc": (H.init_ctc_head, make_ctc_head_loss),
    "medusa": (H.init_medusa_head, make_medusa_head_loss),
    "hydra": (H.init_hydra_head, make_hydra_head_loss),
}


def train_head(kind: str, cfg: dict, base_params, tokens: np.ndarray,
               seed: int = 0, steps: int | None = None, log: Callable = print):
    steps = steps or STEPS_HEAD
    init_fn, loss_maker = HEAD_KINDS[kind]
    hp = init_fn(cfg, jax.random.PRNGKey(seed + 100))
    opt = adamw_init(hp)
    loss_fn = loss_maker(cfg)
    teacher = make_teacher_fn(cfg)
    emb = base_params["emb"]

    @jax.jit
    def train_step(hp, opt, hidden, labels, step):
        loss, grads = jax.value_and_grad(loss_fn)(hp, emb, hidden, labels)
        lr = cosine_lr(step, steps, C.LR_HEAD)
        hp, opt = adamw_update(hp, grads, opt, lr, wd=0.0)
        return hp, opt, loss

    batcher = Batcher(tokens, C.TRAIN_BATCH, C.TRAIN_SEQ, seed + 2)
    losses, t0 = [], time.time()
    for step in range(steps):
        batch = jnp.asarray(batcher.next())
        x = batch[:, :-1]
        labels, hidden = teacher(base_params, x)   # Y_distill (Eq. 5)
        hp, opt, loss = train_step(hp, opt, hidden, labels, step)
        losses.append(float(loss))
        if step % 25 == 0 or step == steps - 1:
            log(f"  {kind}-head step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s)")
    return hp, losses
