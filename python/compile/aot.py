"""AOT driver: corpus → tokenizer → train → lower → artifacts/.

Python runs exactly once (``make artifacts``); the rust coordinator is
self-contained afterwards. Incremental: per-model checkpoints are reused on
rebuild, and manifest.json is rewritten after every model so the rust side
can start as soon as the first model lands.

Usage:
  python -m compile.aot --out ../artifacts [--models vic-tiny,lc2-small|all]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from . import constants as C
from . import corpus as corpus_mod
from . import heads as H
from . import model as M
from . import train as T
from . import tokenizer as tok_mod
from .export import arg_spec, to_hlo_text, write_manifest, write_tensors
from .kernels.ctc_loss import ctc_neg_logp

CTC_SCORE_BATCH = 16


def log(msg: str) -> None:
    print(f"[aot] {msg}", flush=True)
    sys.stdout.flush()


# ----------------------------------------------------------------- lowering
def lower_step_graphs(cfg: dict, out_dir: str, model_name: str) -> dict:
    graphs = {}
    layers, h, dh, d = cfg["layers"], cfg["n_heads"], C.HEAD_DIM, cfg["d_model"]
    fn, names = M.make_step_fn(cfg)
    shapes = M.param_shapes(cfg)
    for b in C.BATCH_SIZES:
        for n in C.STEP_NS:
            specs = [jax.ShapeDtypeStruct(shapes[nm], jnp.float32)
                     for nm in names]
            specs += [
                jax.ShapeDtypeStruct((layers, b, C.LMAX, h, dh), jnp.float32),
                jax.ShapeDtypeStruct((layers, b, C.LMAX, h, dh), jnp.float32),
                jax.ShapeDtypeStruct((b, n), jnp.int32),
                jax.ShapeDtypeStruct((b, n), jnp.int32),
                jax.ShapeDtypeStruct((b, n, C.LMAX + n), jnp.float32),
            ]
            gname = f"step_b{b}_n{n}"
            fname = f"{model_name}.{gname}.hlo.txt"
            text = to_hlo_text(jax.jit(fn).lower(*specs))
            open(os.path.join(out_dir, fname), "w").write(text)
            graphs[gname] = {
                "file": fname, "batch": b, "n": n,
                "args": [arg_spec("weights", [len(names)], "list")] + [
                    arg_spec("kcache", (layers, b, C.LMAX, h, dh), "f32"),
                    arg_spec("vcache", (layers, b, C.LMAX, h, dh), "f32"),
                    arg_spec("tokens", (b, n), "i32"),
                    arg_spec("pos", (b, n), "i32"),
                    arg_spec("bias", (b, n, C.LMAX + n), "f32"),
                ],
                "outputs": [
                    arg_spec("logits", (b, n, C.VOCAB_SIZE), "f32"),
                    arg_spec("k_new", (layers, b, n, h, dh), "f32"),
                    arg_spec("v_new", (layers, b, n, h, dh), "f32"),
                    arg_spec("hidden", (b, n, d), "f32"),
                ],
            }
            log(f"  lowered {gname} ({len(text)} chars)")
    return graphs


def lower_head_graphs(cfg: dict, out_dir: str, model_name: str) -> dict:
    d = cfg["d_model"]
    graphs = {}
    # ---- CTC draft head
    fn, names = H.make_ctc_draft_fn(cfg)
    hshapes = H.ctc_head_shapes(cfg)
    for b in C.BATCH_SIZES:
        specs = [jax.ShapeDtypeStruct(hshapes[nm], jnp.float32) for nm in names]
        specs += [
            jax.ShapeDtypeStruct((C.VOCAB_SIZE, d), jnp.float32),   # emb
            jax.ShapeDtypeStruct((b, C.HIDDEN_WIN, d), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ]
        gname = f"draft_ctc_b{b}"
        fname = f"{model_name}.{gname}.hlo.txt"
        open(os.path.join(out_dir, fname), "w").write(
            to_hlo_text(jax.jit(fn).lower(*specs)))
        graphs[gname] = {
            "file": fname, "batch": b, "head": "ctc",
            "args": [arg_spec("head_weights", [len(names)], "list"),
                     arg_spec("emb", (C.VOCAB_SIZE, d), "f32"),
                     arg_spec("window", (b, C.HIDDEN_WIN, d), "f32"),
                     arg_spec("win_len", (b,), "i32")],
            "outputs": [arg_spec("slot_logp",
                                 (b, C.DRAFT_SLOTS, C.DRAFT_VOCAB), "f32")],
        }
        log(f"  lowered {gname}")
    # ---- Medusa head
    fn, names = H.make_medusa_draft_fn(cfg)
    hshapes = H.medusa_head_shapes(cfg)
    for b in C.BATCH_SIZES:
        specs = [jax.ShapeDtypeStruct(hshapes[nm], jnp.float32) for nm in names]
        specs += [jax.ShapeDtypeStruct((C.VOCAB_SIZE, d), jnp.float32),
                  jax.ShapeDtypeStruct((b, d), jnp.float32)]
        gname = f"draft_medusa_b{b}"
        fname = f"{model_name}.{gname}.hlo.txt"
        open(os.path.join(out_dir, fname), "w").write(
            to_hlo_text(jax.jit(fn).lower(*specs)))
        graphs[gname] = {
            "file": fname, "batch": b, "head": "medusa",
            "args": [arg_spec("head_weights", [len(names)], "list"),
                     arg_spec("emb", (C.VOCAB_SIZE, d), "f32"),
                     arg_spec("hidden", (b, d), "f32")],
            "outputs": [arg_spec("logits",
                                 (b, C.MEDUSA_HEADS, C.VOCAB_SIZE), "f32")],
        }
        log(f"  lowered {gname}")
    # ---- Hydra head (in-graph beam expansion)
    fn, names = H.make_hydra_draft_fn(cfg)
    hshapes = H.hydra_head_shapes(cfg)
    for b in C.BATCH_SIZES:
        specs = [jax.ShapeDtypeStruct(hshapes[nm], jnp.float32) for nm in names]
        specs += [jax.ShapeDtypeStruct((C.VOCAB_SIZE, d), jnp.float32),
                  jax.ShapeDtypeStruct((b, d), jnp.float32),
                  jax.ShapeDtypeStruct((b,), jnp.int32)]
        gname = f"draft_hydra_b{b}"
        fname = f"{model_name}.{gname}.hlo.txt"
        open(os.path.join(out_dir, fname), "w").write(
            to_hlo_text(jax.jit(fn).lower(*specs)))
        graphs[gname] = {
            "file": fname, "batch": b, "head": "hydra",
            "args": [arg_spec("head_weights", [len(names)], "list"),
                     arg_spec("emb", (C.VOCAB_SIZE, d), "f32"),
                     arg_spec("hidden", (b, d), "f32"),
                     arg_spec("base_tok", (b,), "i32")],
            "outputs": [
                arg_spec("beam_tokens",
                         (b, C.HYDRA_BEAMS, C.HYDRA_STEPS), "i32"),
                arg_spec("beam_logp", (b, C.HYDRA_BEAMS), "f32")],
        }
        log(f"  lowered {gname}")
    return graphs


def lower_ctc_score(out_dir: str) -> dict:
    """Standalone Pallas CTC α-DP artifact (candidate rescoring)."""
    b = CTC_SCORE_BATCH

    def fn(logp, targets, tgt_len):
        return (ctc_neg_logp(logp, targets, tgt_len, C.BLANK_ID),)

    specs = [
        jax.ShapeDtypeStruct((b, C.DRAFT_SLOTS, C.DRAFT_VOCAB), jnp.float32),
        jax.ShapeDtypeStruct((b, C.CTC_TARGET_U), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    ]
    gname = f"ctc_score_b{b}"
    fname = f"{gname}.hlo.txt"
    open(os.path.join(out_dir, fname), "w").write(
        to_hlo_text(jax.jit(fn).lower(*specs)))
    log(f"  lowered {gname}")
    return {gname: {
        "file": fname, "batch": b,
        "args": [arg_spec("logp", (b, C.DRAFT_SLOTS, C.DRAFT_VOCAB), "f32"),
                 arg_spec("targets", (b, C.CTC_TARGET_U), "i32"),
                 arg_spec("tgt_len", (b,), "i32")],
        "outputs": [arg_spec("nll", (b,), "f32")],
    }}


# ----------------------------------------------------------------- checkpoints
def ckpt_path(out_dir, name):
    return os.path.join(out_dir, f"ckpt-{name}.npz")


def save_ckpt(out_dir, name, params):
    np.savez(ckpt_path(out_dir, name),
             **{k: np.asarray(v) for k, v in params.items()})


def load_ckpt(out_dir, name):
    path = ckpt_path(out_dir, name)
    if not os.path.exists(path):
        return None
    data = np.load(path)
    return {k: jnp.asarray(data[k]) for k in data.files}


# ----------------------------------------------------------------- per-model build
def build_model(model_name: str, out_dir: str, tokens_by_family: dict,
                manifest: dict) -> None:
    cfg = dict(C.MODELS[model_name])
    tokens = tokens_by_family[cfg["family"]]
    log(f"=== {model_name} (analog {cfg['analog']}) ===")

    params = load_ckpt(out_dir, model_name)
    if params is None:
        t0 = time.time()
        params, losses = T.train_base(
            cfg, tokens, seed=zlib.crc32(model_name.encode()) % 2 ** 16,
            log=log)
        log(f"base trained in {time.time() - t0:.0f}s "
            f"(loss {losses[0]:.3f} -> {losses[-1]:.3f})")
        save_ckpt(out_dir, model_name, params)
    else:
        log("base checkpoint reused")

    head_params = {}
    for kind in ("ctc", "medusa", "hydra"):
        hname = f"{model_name}.head-{kind}"
        hp = load_ckpt(out_dir, hname)
        if hp is None:
            t0 = time.time()
            hp, losses = T.train_head(
                kind, cfg, params, tokens,
                seed=zlib.crc32(hname.encode()) % 2 ** 16, log=log)
            log(f"{kind} head trained in {time.time() - t0:.0f}s "
                f"(loss {losses[0]:.3f} -> {losses[-1]:.3f})")
            save_ckpt(out_dir, hname, hp)
        else:
            log(f"{kind} head checkpoint reused")
        head_params[kind] = hp

    # ---- weights
    worder = M.weight_names(cfg)
    wfile = f"{model_name}.tensors.bin"
    write_tensors(os.path.join(out_dir, wfile),
                  {k: np.asarray(v, np.float32) for k, v in params.items()},
                  worder)
    heads_meta = {}
    head_orders = {"ctc": H.ctc_head_names(), "medusa": H.medusa_head_names(),
                   "hydra": H.hydra_head_names()}
    for kind, hp in head_params.items():
        hfile = f"{model_name}.head-{kind}.tensors.bin"
        write_tensors(os.path.join(out_dir, hfile),
                      {k: np.asarray(v, np.float32) for k, v in hp.items()},
                      head_orders[kind])
        heads_meta[kind] = {"weights": hfile, "weight_order": head_orders[kind]}

    # ---- graphs
    graphs = {}
    graphs.update(lower_step_graphs(cfg, out_dir, model_name))
    graphs.update(lower_head_graphs(cfg, out_dir, model_name))

    manifest["models"][model_name] = {
        "config": cfg,
        "weights": wfile,
        "weight_order": worder,
        "heads": heads_meta,
        "graphs": graphs,
    }


# ----------------------------------------------------------------- main
def base_manifest() -> dict:
    return {
        "version": C.MANIFEST_VERSION,
        "constants": {
            "vocab_size": C.VOCAB_SIZE, "blank_id": C.BLANK_ID,
            "pad_id": C.PAD_ID, "bos_id": C.BOS_ID, "eos_id": C.EOS_ID,
            "lmax": C.LMAX, "tree_n": C.TREE_N, "prefill_n": C.PREFILL_N,
            "draft_slots": C.DRAFT_SLOTS, "ctc_target_u": C.CTC_TARGET_U,
            "hidden_win": C.HIDDEN_WIN, "medusa_heads": C.MEDUSA_HEADS,
            "hydra_steps": C.HYDRA_STEPS, "hydra_beams": C.HYDRA_BEAMS,
            "head_dim": C.HEAD_DIM, "batch_sizes": list(C.BATCH_SIZES),
            "step_ns": list(C.STEP_NS),
            "ctc_score_batch": CTC_SCORE_BATCH,
        },
        "tokenizer": "vocab.json",
        "chat_templates": {k: list(v) for k, v in C.CHAT_TEMPLATES.items()},
        "models": {},
        "kernels": {},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all",
                    help="comma list or 'all'")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    model_names = (list(C.MODELS) if args.models == "all"
                   else args.models.split(","))
    for m in model_names:
        assert m in C.MODELS, m

    # corpus + tokenizer (shared across families)
    corpora = {}
    for fam in ("vic", "lc2"):
        cpath = os.path.join(out_dir, f"corpus-{fam}.txt")
        if os.path.exists(cpath):
            corpora[fam] = open(cpath).read()
        else:
            log(f"building corpus for family {fam}")
            corpora[fam] = corpus_mod.build_corpus(fam, seed=0)
            open(cpath, "w").write(corpora[fam])

    vocab_path = os.path.join(out_dir, "vocab.json")
    if os.path.exists(vocab_path):
        bpe = tok_mod.ByteBpe.load(vocab_path)
        log("tokenizer reused")
    else:
        log("training byte-BPE tokenizer")
        bpe = tok_mod.train_bpe(corpora["vic"] + corpora["lc2"])
        bpe.save(vocab_path)
        log(f"tokenizer trained: vocab {bpe.vocab_size}")

    tokens_by_family = {
        fam: np.asarray(bpe.encode(text), np.int32)
        for fam, text in corpora.items()
    }
    for fam, toks in tokens_by_family.items():
        log(f"family {fam}: {len(toks)} tokens")

    mpath = os.path.join(out_dir, "manifest.json")
    manifest = base_manifest()
    # keep already-built models when re-running with a subset
    if os.path.exists(mpath):
        old = json.load(open(mpath))
        if old.get("version") == C.MANIFEST_VERSION:
            manifest["models"].update(old.get("models", {}))
            manifest["kernels"].update(old.get("kernels", {}))

    manifest["kernels"].update(lower_ctc_score(out_dir))
    write_manifest(mpath, manifest)

    for m in model_names:
        build_model(m, out_dir, tokens_by_family, manifest)
        write_manifest(mpath, manifest)
        log(f"manifest updated with {m}")

    log("done")


if __name__ == "__main__":
    main()
