"""Training-corpus construction.

The paper trains draft heads on ShareGPT (multi-turn chat). We have no
network access, so the corpus is built from two real local sources plus a
seeded synthetic dialogue generator whose question distribution matches the
rust-side evaluation workload (``rust/src/workload``):

  1. prose harvested from the python stdlib (docstrings — real English text),
  2. real code snippets from the stdlib (feeds the "coding" category),
  3. synthetic multi-turn Q/A dialogues across the 8 MT-bench categories,
     chat-templated per model family ("vic" vs "lc2").

Everything is deterministic given the seed.
"""

from __future__ import annotations

import ast
import glob
import os
import random
import sysconfig

from . import constants as C

# Words banks for template expansion; deliberately small so a ~1M-param model
# can actually learn the distribution (that is the point: acceptance-rate
# dynamics need a base model whose argmax a small head can imitate).
_TOPICS = ["the ocean", "a small village", "the night sky", "an old library",
           "a mountain trail", "the harvest season", "a river crossing",
           "the city market", "a winter storm", "an ancient map"]
_QUALITIES = ["quiet", "bright", "ancient", "restless", "gentle", "vast",
              "hidden", "familiar", "distant", "golden"]
_ROLES = ["a ship captain", "a museum guide", "a village doctor",
          "a night watchman", "a railway engineer", "a lighthouse keeper"]
_FACTS = {
    "stem": [("why is the sky blue",
              "Sunlight scatters off air molecules, and blue light scatters "
              "more strongly than red light, so the sky appears blue."),
             ("what causes tides",
              "Tides are caused by the gravitational pull of the moon and "
              "the sun on the ocean."),
             ("how do plants make food",
              "Plants use photosynthesis: they turn sunlight, water and "
              "carbon dioxide into sugar and oxygen."),
             ("what is an atom",
              "An atom is the smallest unit of matter, made of protons, "
              "neutrons and electrons."),
             ("why do seasons change",
              "Seasons change because the earth's axis is tilted as it "
              "orbits the sun.")],
    "humanities": [("who writes history",
                    "History is written by historians who study documents, "
                    "objects and accounts from the past."),
                   ("what is a myth",
                    "A myth is a traditional story that explains the beliefs "
                    "or customs of a people."),
                   ("why do cities form near rivers",
                    "Rivers provide water, food and transport, so early "
                    "cities grew along their banks."),
                   ("what is a constitution",
                    "A constitution is the set of basic rules by which a "
                    "country is governed.")],
}


def _sentence(rng: random.Random) -> str:
    t = rng.choice(_TOPICS)
    q = rng.choice(_QUALITIES)
    forms = [
        f"The {q} light settled over {t}.",
        f"People spoke of {t} as something {q}.",
        f"In the morning, {t} seemed {q} and still.",
        f"Nothing about {t} felt {q} that day.",
        f"She remembered {t}, {q} as ever.",
    ]
    return rng.choice(forms)


def _gen_writing(rng):
    t = rng.choice(_TOPICS)
    q = f"Write a short paragraph about {t}."
    a = " ".join(_sentence(rng) for _ in range(rng.randint(3, 5)))
    return q, a


def _gen_roleplay(rng):
    r = rng.choice(_ROLES)
    q = f"Pretend you are {r}. Introduce yourself."
    a = (f"Greetings. I am {r}, and I have held this post for many years. "
         f"Ask me anything about my work and I will answer plainly.")
    return q, a


def _gen_reasoning(rng):
    a1, a2 = rng.randint(2, 9), rng.randint(2, 9)
    q = (f"If a box holds {a1} red balls and {a2} blue balls, "
         f"how many balls are in the box?")
    a = (f"There are {a1} red balls and {a2} blue balls, "
         f"so the box holds {a1} + {a2} = {a1 + a2} balls in total.")
    return q, a


def _gen_math(rng):
    kind = rng.randrange(3)
    if kind == 0:
        x, y = rng.randint(10, 99), rng.randint(10, 99)
        q = f"What is {x} + {y}?"
        a = f"{x} + {y} = {x + y}."
    elif kind == 1:
        x, y = rng.randint(2, 12), rng.randint(2, 12)
        q = f"What is {x} times {y}?"
        a = f"{x} times {y} is {x * y}."
    else:
        n, p = rng.randint(3, 9), rng.randint(2, 9)
        total = n * p
        q = (f"A farmer packs {total} apples into boxes of {p}. "
             f"How many boxes does he fill?")
        a = (f"Each box holds {p} apples, so he fills "
             f"{total} / {p} = {n} boxes.")
    return q, a


def _gen_coding(rng):
    fn = rng.choice(["add", "double", "square", "negate", "half"])
    body = {
        "add": "def add(a, b):\n    return a + b",
        "double": "def double(x):\n    return x * 2",
        "square": "def square(x):\n    return x * x",
        "negate": "def negate(x):\n    return -x",
        "half": "def half(x):\n    return x / 2",
    }[fn]
    q = f"Write a python function named {fn}."
    a = f"Here is the function:\n```python\n{body}\n```"
    return q, a


def _gen_extraction(rng):
    name = rng.choice(["Ada", "Bruno", "Clara", "Daniel", "Elena"])
    city = rng.choice(["Lisbon", "Oslo", "Kyoto", "Quito", "Cairo"])
    year = rng.randint(1990, 2020)
    q = (f"Extract the name, city and year from: '{name} moved to {city} "
         f"in {year} to study music.'")
    a = f"Name: {name}. City: {city}. Year: {year}."
    return q, a


def _gen_fact(rng, cat):
    q, a = rng.choice(_FACTS[cat])
    return q.capitalize() + "?", a


_GENERATORS = {
    "writing": _gen_writing,
    "roleplay": _gen_roleplay,
    "reasoning": _gen_reasoning,
    "math": _gen_math,
    "coding": _gen_coding,
    "extraction": _gen_extraction,
    "stem": lambda rng: _gen_fact(rng, "stem"),
    "humanities": lambda rng: _gen_fact(rng, "humanities"),
}


def gen_dialogue(rng: random.Random, category: str) -> tuple[str, str]:
    """One (question, answer) pair for an MT-bench-style category."""
    return _GENERATORS[category](rng)


def harvest_stdlib_prose(max_bytes: int = 150_000) -> str:
    """Docstring prose from the python stdlib — real English text."""
    std = sysconfig.get_paths()["stdlib"]
    out, total = [], 0
    for path in sorted(glob.glob(os.path.join(std, "*.py"))):
        try:
            tree = ast.parse(open(path, encoding="utf-8", errors="ignore").read())
        except SyntaxError:
            continue
        doc = ast.get_docstring(tree)
        if doc:
            doc = " ".join(doc.split())
            out.append(doc)
            total += len(doc)
        if total >= max_bytes:
            break
    return "\n".join(out)[:max_bytes]


def harvest_stdlib_code(max_bytes: int = 60_000) -> str:
    """Short real code snippets (defs) from small stdlib modules."""
    std = sysconfig.get_paths()["stdlib"]
    picks = ["bisect.py", "heapq.py", "keyword.py", "stat.py", "token.py"]
    out, total = [], 0
    for name in picks:
        path = os.path.join(std, name)
        if not os.path.exists(path):
            continue
        text = open(path, encoding="utf-8", errors="ignore").read()
        out.append(text)
        total += len(text)
        if total >= max_bytes:
            break
    return "\n".join(out)[:max_bytes]


def build_corpus(family: str, seed: int = 0, target_bytes: int = 600_000) -> str:
    """Full training corpus for one model family, chat-templated."""
    tmpl, _ = C.CHAT_TEMPLATES[family]
    rng = random.Random(seed * 7919 + (13 if family == "lc2" else 0))
    parts = [harvest_stdlib_prose(), harvest_stdlib_code()]
    total = sum(len(p) for p in parts)
    cats = list(C.MTBENCH_CATEGORIES)
    while total < target_bytes:
        cat = rng.choice(cats)
        q, a = gen_dialogue(rng, cat)
        d = tmpl.format(q=q, a=a)
        parts.append(d)
        total += len(d)
    return "\n".join(parts)
